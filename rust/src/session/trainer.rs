//! Distributed training through the session: a [`ModelSpec`] names the
//! parameter slots of a loss query, [`Session::trainer`] compiles it
//! against the catalog (data slots bind to registered tables by scan
//! name), and [`SessionTrainer::step`] runs taped forward + generated
//! backward on the session pool, returning *named* gradients and
//! accumulating per-step [`ExecStats`] on the session.
//!
//! This subsumes the deprecated `DistTrainer::new` →
//! `pipeline(layouts)` → `step_in(pool, …)` dance:
//!
//! * slots are addressed by **name** (the forward query's `TableScan`
//!   names), not by positional index — a reordered slot list cannot
//!   silently swap a parameter for a data table;
//! * the session catalog *is* the partition cache: data tables are
//!   placed once at registration and reused every step (zero
//!   re-partitioning, the `TrainPipeline` guarantee);
//! * the session pool serves every step — `for_worker` runs once per
//!   worker per session, however many steps the loop takes.
//!
//! Data slots are *live*: every [`SessionTrainer::step`] first pulls any
//! [`Session::insert`]/[`Session::delete`] batches applied since the
//! last step into its slot snapshots — merged heads swap in by `Arc`
//! handle (no re-ingest, no reshuffle; replayed rows land in
//! `ExecStats::delta_rows_applied`), so a streaming-update training loop
//! never re-registers its tables. A dropped table freezes at its
//! snapshot; a dropped-and-reregistered one refuses with
//! [`SessionError::StaleEpoch`].
//!
//! Training runs are killable: [`SessionTrainer::checkpoint`] persists
//! the step counter, every named parameter value (through the
//! `dist::spill` columnar codec — bit-exact), each parameter's
//! partitioning metadata, and the update epoch of every bound data
//! table; [`Session::restore_trainer`] validates the manifest against
//! the spec *and the catalog epochs* (resuming against
//! differently-updated data is a typed [`SessionError::StaleEpoch`],
//! never a silent drift) and resumes *bitwise identically* — the
//! restored run's losses and gradients match the uninterrupted run's,
//! bit for bit.

use std::fs;
use std::path::Path;

use super::{Session, SessionError};
use crate::dist::spill::{SpillFile, SpillReader, SpillWriter};
use crate::dist::{ExecStats, PartitionedRelation};
use crate::ml::train::step_core;
use crate::ml::{DistTrainer, SlotLayout};
use crate::ra::expr::Query;
use crate::ra::Relation;

/// One parameter slot declaration: scan name, key arity, cluster layout.
#[derive(Clone, Debug)]
struct ParamSpec {
    name: String,
    arity: usize,
    layout: SlotLayout,
}

/// What to train: a loss query plus its named parameter slots. Every
/// other input slot is a *data* slot and binds to the session table
/// registered under the same name as its `TableScan`.
///
/// ```
/// use relad::ml::gcn::{self, GcnConfig};
/// use relad::session::ModelSpec;
///
/// let cfg = GcnConfig { feat_dim: 8, hidden: 8, n_labels: 4, dropout: None, seed: 1 };
/// let spec = ModelSpec::new(gcn::loss_query(&cfg, 10))
///     .param("W1", 1)
///     .param("W2", 1);
/// assert_eq!(spec.param_names(), ["W1", "W2"]);
/// ```
#[derive(Clone)]
pub struct ModelSpec {
    query: Query,
    params: Vec<ParamSpec>,
}

impl ModelSpec {
    pub fn new(query: Query) -> ModelSpec {
        ModelSpec {
            query,
            params: Vec::new(),
        }
    }

    /// Declare the scan named `name` (key width `arity`) a trainable
    /// parameter, replicated onto every worker (the usual layout for
    /// weight tables — the optimizer delta must reach all shards).
    pub fn param(self, name: &str, arity: usize) -> ModelSpec {
        self.param_with_layout(name, arity, SlotLayout::Replicated)
    }

    /// As [`param`](Self::param) with an explicit layout (e.g. large
    /// factor matrices hash-partitioned instead of replicated).
    pub fn param_with_layout(mut self, name: &str, arity: usize, layout: SlotLayout) -> ModelSpec {
        self.params.push(ParamSpec {
            name: name.to_string(),
            arity,
            layout,
        });
        self
    }

    /// Declared parameter names, in declaration order.
    pub fn param_names(&self) -> Vec<&str> {
        self.params.iter().map(|p| p.name.as_str()).collect()
    }
}

/// One training step's outputs, with gradients addressed by parameter
/// name (the session analogue of `ml::StepResult`).
pub struct NamedStep {
    pub loss: f32,
    /// `(parameter name, gathered gradient relation)` in [`ModelSpec`]
    /// declaration order.
    pub grads: Vec<(String, Relation)>,
    /// This step's execution stats (also merged into the session total).
    pub stats: ExecStats,
}

impl NamedStep {
    /// The gradient of one named parameter, if it was requested.
    pub fn grad(&self, name: &str) -> Option<&Relation> {
        self.grads
            .iter()
            .find_map(|(n, g)| (n == name).then_some(g))
    }
}

/// A compiled training loop bound to a session: forward + generated
/// backward share the session pool, data tables come from the catalog
/// (placed once), and parameters are re-homed each step. Built by
/// [`Session::trainer`].
pub struct SessionTrainer<'s> {
    sess: &'s Session,
    trainer: DistTrainer,
    /// Catalog table name per forward input slot (params + data).
    slot_names: Vec<String>,
    /// `(slot, declared key arity, layout)` of each parameter, in
    /// declaration order.
    param_slots: Vec<(usize, usize, SlotLayout)>,
    /// Cached placements for data slots (`None` at parameter slots) —
    /// handle copies of the catalog partitions, snapshotted at compile
    /// and refreshed from the catalog delta log at every step.
    data: Vec<Option<PartitionedRelation>>,
    /// `(identity generation, update epoch)` each data slot was bound at
    /// (`None` at parameter slots) — how a step tells "same table, more
    /// epochs" from "different table wearing the same name".
    data_binds: Vec<Option<(u64, u64)>>,
    steps: u64,
}

impl<'s> SessionTrainer<'s> {
    pub(crate) fn compile(sess: &'s Session, spec: ModelSpec) -> Result<Self, SessionError> {
        let slot_names = super::scan_names(&spec.query)?;
        let n = slot_names.len();
        let mut param_slots = Vec::with_capacity(spec.params.len());
        let mut arities = vec![0usize; n];
        let mut data: Vec<Option<PartitionedRelation>> = vec![None; n];
        for p in &spec.params {
            let slot = slot_names
                .iter()
                .position(|s| *s == p.name)
                .ok_or_else(|| SessionError::UnknownTable(p.name.clone()))?;
            if param_slots.iter().any(|&(s, _, _)| s == slot) {
                return Err(SessionError::Invalid(format!(
                    "parameter {} declared twice",
                    p.name
                )));
            }
            arities[slot] = p.arity;
            param_slots.push((slot, p.arity, p.layout.clone()));
        }
        let mut data_binds: Vec<Option<(u64, u64)>> = vec![None; n];
        for (slot, name) in slot_names.iter().enumerate() {
            if param_slots.iter().any(|&(s, _, _)| s == slot) {
                continue;
            }
            // Data slots bind to catalog tables by scan name, at the
            // table's current generation and epoch.
            let (part, gen, epoch, _) = sess
                .table_delta_state(name)
                .ok_or_else(|| SessionError::UnknownTable(name.clone()))?;
            arities[slot] = sess.table_arity(name).unwrap_or(0);
            data[slot] = Some(part);
            data_binds[slot] = Some((gen, epoch));
        }
        let wrt: Vec<usize> = param_slots.iter().map(|&(s, _, _)| s).collect();
        let trainer = DistTrainer::new(spec.query, &arities, &wrt)
            .map_err(|e| SessionError::NotDifferentiable(format!("{e:#}")))?;
        Ok(SessionTrainer {
            sess,
            trainer,
            slot_names,
            param_slots,
            data,
            data_binds,
            steps: 0,
        })
    }

    /// The compiled forward/backward pair (e.g. to inspect the generated
    /// backward query).
    pub fn compiled(&self) -> &DistTrainer {
        &self.trainer
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Re-snapshot the data slots from the session catalog (call after
    /// re-registering a table, e.g. a new mini-batch sample). Unlike the
    /// per-step delta refresh, this accepts a changed identity
    /// generation — it is the explicit "bind me to whatever is there
    /// now" escape hatch.
    pub fn rebind(&mut self) -> Result<(), SessionError> {
        for (slot, name) in self.slot_names.iter().enumerate() {
            if self.param_slots.iter().any(|&(s, _, _)| s == slot) {
                continue;
            }
            let (part, gen, epoch, _) = self
                .sess
                .table_delta_state(name)
                .ok_or_else(|| SessionError::UnknownTable(name.clone()))?;
            self.data[slot] = Some(part);
            self.data_binds[slot] = Some((gen, epoch));
        }
        Ok(())
    }

    /// Pull any catalog deltas applied since the last step into the data
    /// slots: merged heads swap in by `Arc` handle (no re-ingest, no
    /// reshuffle), and the replayed rows are charged to
    /// `ExecStats::delta_rows_applied`. A dropped table keeps training
    /// on its frozen snapshot; a dropped-and-reregistered one (new
    /// identity generation) refuses with [`SessionError::StaleEpoch`].
    fn refresh_data(&mut self) -> Result<(), SessionError> {
        for (slot, name) in self.slot_names.iter().enumerate() {
            let Some(bind) = self.data_binds[slot] else {
                continue;
            };
            let Some((head, gen, epoch, batches)) = self.sess.table_delta_state(name) else {
                continue; // dropped: frozen snapshot
            };
            if gen != bind.0 {
                return Err(SessionError::StaleEpoch {
                    table: name.clone(),
                    bound: bind.0,
                    current: gen,
                });
            }
            if epoch == bind.1 {
                continue;
            }
            let rows: u64 = batches[bind.1 as usize..epoch as usize]
                .iter()
                .map(|&(_, r)| r)
                .sum();
            self.sess.charge_delta_rows(rows);
            self.data[slot] = Some(head);
            self.data_binds[slot] = Some((gen, epoch));
        }
        Ok(())
    }

    /// One training step. `params` supplies the current value of every
    /// declared parameter by name (any order); data slots are served from
    /// the catalog snapshot. Parameters are re-homed under their layout
    /// (their values change every step) and the ingest is charged to the
    /// step's stats; data moves zero bytes.
    pub fn step(&mut self, params: &[(&str, &Relation)]) -> Result<NamedStep, SessionError> {
        self.refresh_data()?;
        let w = self.sess.workers();
        let cfg = self.sess.cfg();
        let mut placed: Vec<Option<PartitionedRelation>> = self.data.clone();
        let mut ingest = 0u64;
        let mut ingest_s = 0.0f64;
        for &(slot, arity, ref layout) in &self.param_slots {
            let name = &self.slot_names[slot];
            let (_, rel) = params
                .iter()
                .find(|(n, _)| n == name)
                .ok_or_else(|| {
                    SessionError::Invalid(format!("no value supplied for parameter {name}"))
                })?;
            super::check_arity(name, arity, rel.key_arity())?;
            let bytes = layout.ingest_bytes(rel.nbytes() as u64, w);
            ingest += bytes;
            ingest_s += layout.ingest_time(&cfg.net, bytes, w);
            placed[slot] = Some(layout.place(rel, w));
        }
        for (n, _) in params {
            if !self
                .param_slots
                .iter()
                .any(|&(s, _, _)| self.slot_names[s] == *n)
            {
                return Err(SessionError::Invalid(format!(
                    "{n} is not a declared parameter of this trainer"
                )));
            }
        }
        let inputs: Vec<PartitionedRelation> = placed
            .into_iter()
            .map(|p| p.expect("every slot is a param or bound data"))
            .collect();
        let res = step_core(
            &self.trainer,
            &inputs,
            cfg,
            self.sess.backend(),
            self.sess.pool(),
        )?;
        let mut stats = res.stats;
        stats.bytes_ingested += ingest;
        stats.net_s += ingest_s;
        stats.virtual_time_s += ingest_s;
        self.sess.merge_stats(&stats);
        self.steps += 1;
        // Gradients arrive slot-addressed from the core; hand them back
        // name-addressed in declaration order, *moving* each relation
        // (no gradient is ever deep-copied).
        let mut slot_grads = res.grads;
        let mut grads = Vec::with_capacity(self.param_slots.len());
        for &(slot, _, _) in &self.param_slots {
            let idx = slot_grads
                .iter()
                .position(|(s, _)| *s == slot)
                .ok_or_else(|| {
                    SessionError::Invalid(format!(
                        "backward plan produced no gradient for slot {slot}"
                    ))
                })?;
            let (_, g) = slot_grads.swap_remove(idx);
            grads.push((self.slot_names[slot].clone(), g));
        }
        Ok(NamedStep {
            loss: res.loss,
            grads,
            stats,
        })
    }

    /// Persist this training run to `dir` (created if missing): the step
    /// counter, every declared parameter's current value (`params`, by
    /// name, any order — the same shape [`step`](Self::step) takes),
    /// each parameter's partitioning metadata, and the update epoch of
    /// every bound data table (restore refuses any other epoch with
    /// [`SessionError::StaleEpoch`]). Values go through the
    /// `dist::spill` columnar codec (`p0.spill`, `p1.spill`, … in
    /// declaration order; bit-exact little-endian round trip), and the
    /// binary `MANIFEST` is sealed *last* via a temp-file rename — a run
    /// killed mid-checkpoint leaves no manifest, so
    /// [`Session::restore_trainer`] cleanly rejects the partial state
    /// instead of resuming from it.
    ///
    /// Returns the total bytes written; the same amount is merged into
    /// the session's [`ExecStats::checkpoint_bytes`].
    pub fn checkpoint(
        &self,
        dir: &Path,
        params: &[(&str, &Relation)],
    ) -> Result<u64, SessionError> {
        let io_err = |what: &str, e: std::io::Error| {
            SessionError::Invalid(format!("checkpoint {}: {e}", what))
        };
        fs::create_dir_all(dir).map_err(|e| io_err("dir", e))?;
        let mut manifest = Vec::new();
        manifest.extend_from_slice(&CKPT_MAGIC);
        manifest.extend_from_slice(&self.steps.to_le_bytes());
        manifest.extend_from_slice(&(self.sess.workers() as u32).to_le_bytes());
        // v2: the update epoch of every bound data table, in slot order.
        // Restore refuses a catalog at any other epoch — a checkpointed
        // optimizer state only resumes bitwise against the data it was
        // trained on.
        let data_slots: Vec<usize> = (0..self.slot_names.len())
            .filter(|&s| self.data_binds[s].is_some())
            .collect();
        manifest.extend_from_slice(&(data_slots.len() as u32).to_le_bytes());
        for &slot in &data_slots {
            let name = &self.slot_names[slot];
            let (_, epoch) = self.data_binds[slot].expect("data slot has a bind");
            manifest.extend_from_slice(&(name.len() as u32).to_le_bytes());
            manifest.extend_from_slice(name.as_bytes());
            manifest.extend_from_slice(&epoch.to_le_bytes());
        }
        manifest.extend_from_slice(&(self.param_slots.len() as u32).to_le_bytes());
        let mut total = 0u64;
        for (i, &(slot, arity, ref layout)) in self.param_slots.iter().enumerate() {
            let name = &self.slot_names[slot];
            let (_, rel) = params.iter().find(|(n, _)| n == name).ok_or_else(|| {
                SessionError::Invalid(format!("no value supplied for parameter {name}"))
            })?;
            super::check_arity(name, arity, rel.key_arity())?;
            let path = dir.join(format!("p{i}.spill"));
            let mut w = SpillWriter::create_at(&path)
                .map_err(|e| io_err("param file", e))?;
            w.write_run(rel.pairs()).map_err(|e| io_err("param write", e))?;
            let file = w.finish().map_err(|e| io_err("param seal", e))?;
            let (nbytes, runs) = (file.nbytes(), file.runs());
            file.keep();
            total += nbytes;
            manifest.extend_from_slice(&(name.len() as u32).to_le_bytes());
            manifest.extend_from_slice(name.as_bytes());
            manifest.extend_from_slice(&(arity as u32).to_le_bytes());
            encode_layout(&mut manifest, layout);
            manifest.extend_from_slice(&runs.to_le_bytes());
            manifest.extend_from_slice(&nbytes.to_le_bytes());
        }
        // Seal: the manifest appears atomically, and only after every
        // parameter file it describes is durable.
        let tmp = dir.join("MANIFEST.tmp");
        fs::write(&tmp, &manifest).map_err(|e| io_err("manifest write", e))?;
        fs::rename(&tmp, dir.join("MANIFEST")).map_err(|e| io_err("manifest seal", e))?;
        total += manifest.len() as u64;
        self.sess.merge_stats(&ExecStats {
            checkpoint_bytes: total,
            ..ExecStats::default()
        });
        Ok(total)
    }
}

/// Checkpoint manifest magic. Format version 2 added the data-table
/// epoch section between the worker count and the parameter count; v1
/// manifests are refused by the magic check (re-checkpoint to upgrade).
const CKPT_MAGIC: [u8; 8] = *b"RELADCK2";

fn encode_layout(buf: &mut Vec<u8>, layout: &SlotLayout) {
    match layout {
        SlotLayout::Replicated => buf.push(0),
        SlotLayout::HashOn(comps) => {
            buf.push(1);
            buf.extend_from_slice(&(comps.len() as u32).to_le_bytes());
            for &c in comps {
                buf.extend_from_slice(&(c as u32).to_le_bytes());
            }
        }
        SlotLayout::HashFull => buf.push(2),
    }
}

/// Little-endian cursor over the manifest bytes; every read is
/// bounds-checked so a truncated or corrupt manifest is a typed
/// [`SessionError::Invalid`], never a panic.
struct Cursor<'b> {
    buf: &'b [u8],
    pos: usize,
}

impl<'b> Cursor<'b> {
    fn take<const N: usize>(&mut self) -> Result<[u8; N], SessionError> {
        let end = self.pos + N;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            SessionError::Invalid("checkpoint manifest truncated".to_string())
        })?;
        self.pos = end;
        Ok(s.try_into().expect("slice length is N"))
    }

    fn take_u32(&mut self) -> Result<u32, SessionError> {
        Ok(u32::from_le_bytes(self.take()?))
    }

    fn take_u64(&mut self) -> Result<u64, SessionError> {
        Ok(u64::from_le_bytes(self.take()?))
    }

    fn take_str(&mut self, n: usize) -> Result<String, SessionError> {
        let end = self.pos + n;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            SessionError::Invalid("checkpoint manifest truncated".to_string())
        })?;
        self.pos = end;
        String::from_utf8(s.to_vec())
            .map_err(|_| SessionError::Invalid("checkpoint name not UTF-8".to_string()))
    }

    fn take_layout(&mut self) -> Result<SlotLayout, SessionError> {
        let [tag] = self.take::<1>()?;
        Ok(match tag {
            0 => SlotLayout::Replicated,
            1 => {
                let n = self.take_u32()? as usize;
                let mut comps = Vec::with_capacity(n);
                for _ in 0..n {
                    comps.push(self.take_u32()? as usize);
                }
                SlotLayout::HashOn(comps)
            }
            2 => SlotLayout::HashFull,
            t => {
                return Err(SessionError::Invalid(format!(
                    "checkpoint layout tag {t} unknown"
                )))
            }
        })
    }
}

impl Session {
    /// Rebuild a training run from a [`SessionTrainer::checkpoint`]:
    /// compile `spec` against this session's catalog, validate the
    /// manifest against it (worker count, parameter names, arities,
    /// layouts — a checkpoint never silently rebinds to a different
    /// model or cluster shape), restore the step counter, and read every
    /// parameter value back bit-exactly. Returns the trainer plus the
    /// restored `(name, value)` pairs in declaration order; feeding them
    /// to [`SessionTrainer::step`] resumes the killed run
    /// bitwise-identically. The checkpoint itself is left intact.
    pub fn restore_trainer(
        &self,
        dir: &Path,
        spec: ModelSpec,
    ) -> Result<(SessionTrainer<'_>, Vec<(String, Relation)>), SessionError> {
        let bytes = fs::read(dir.join("MANIFEST")).map_err(|e| {
            SessionError::Invalid(format!(
                "checkpoint manifest {}: {e}",
                dir.join("MANIFEST").display()
            ))
        })?;
        let mut cur = Cursor { buf: &bytes, pos: 0 };
        if cur.take::<8>()? != CKPT_MAGIC {
            return Err(SessionError::Invalid(
                "checkpoint manifest magic mismatch".to_string(),
            ));
        }
        let steps = cur.take_u64()?;
        let workers = cur.take_u32()? as usize;
        if workers != self.workers() {
            return Err(SessionError::Invalid(format!(
                "checkpoint taken on {workers} worker(s), session has {}",
                self.workers()
            )));
        }
        let mut trainer = SessionTrainer::compile(self, spec)?;
        // v2 data-table epoch section: every bound table must sit at the
        // exact epoch the run was checkpointed against. A table that took
        // inserts/deletes since (or was dropped and re-registered, which
        // also resets its epoch log) is a typed refusal — resuming a run
        // against different data would not be the run that was saved.
        let n_tables = cur.take_u32()? as usize;
        let bound: usize = trainer.data_binds.iter().filter(|b| b.is_some()).count();
        if n_tables != bound {
            return Err(SessionError::Invalid(format!(
                "checkpoint records {n_tables} data table(s), spec binds {bound}"
            )));
        }
        for _ in 0..n_tables {
            let len = cur.take_u32()? as usize;
            let name = cur.take_str(len)?;
            let ck_epoch = cur.take_u64()?;
            let Some((_, _, cur_epoch, _)) = self.table_delta_state(&name) else {
                return Err(SessionError::UnknownTable(name));
            };
            if cur_epoch != ck_epoch {
                return Err(SessionError::StaleEpoch {
                    table: name,
                    bound: ck_epoch,
                    current: cur_epoch,
                });
            }
        }
        let n_params = cur.take_u32()? as usize;
        if n_params != trainer.param_slots.len() {
            return Err(SessionError::Invalid(format!(
                "checkpoint has {n_params} parameter(s), spec declares {}",
                trainer.param_slots.len()
            )));
        }
        let mut values = Vec::with_capacity(n_params);
        for (i, &(slot, arity, ref layout)) in trainer.param_slots.iter().enumerate() {
            let name = &trainer.slot_names[slot];
            let len = cur.take_u32()? as usize;
            let ck_name = cur.take_str(len)?;
            if ck_name != *name {
                return Err(SessionError::Invalid(format!(
                    "checkpoint parameter {i} is {ck_name}, spec declares {name}"
                )));
            }
            let ck_arity = cur.take_u32()? as usize;
            if ck_arity != arity {
                return Err(SessionError::ArityMismatch {
                    table: ck_name,
                    expected: arity,
                    got: ck_arity,
                });
            }
            let ck_layout = cur.take_layout()?;
            if ck_layout != *layout {
                return Err(SessionError::Invalid(format!(
                    "checkpoint layout of {ck_name} is {ck_layout:?}, spec declares {layout:?}"
                )));
            }
            let runs = cur.take_u64()?;
            let nbytes = cur.take_u64()?;
            let path = dir.join(format!("p{i}.spill"));
            let file = SpillFile::attach(&path, runs).map_err(|e| {
                SessionError::Invalid(format!("checkpoint param {}: {e}", path.display()))
            })?;
            if file.nbytes() != nbytes {
                // Refuse a torn parameter file (size drifted since the
                // manifest sealed) before handing it to the reader; keep
                // the evidence on disk.
                let _ = file.keep();
                return Err(SessionError::Invalid(format!(
                    "checkpoint param {} is {} byte(s), manifest says {nbytes}",
                    path.display(),
                    fs::metadata(&path).map(|m| m.len()).unwrap_or(0)
                )));
            }
            let mut pairs = Vec::new();
            let mut reader = SpillReader::open(&file).map_err(|e| {
                SessionError::Invalid(format!("checkpoint param {}: {e}", path.display()))
            })?;
            while let Some(run) = reader.next_run().map_err(|e| {
                SessionError::Invalid(format!("checkpoint param {}: {e}", path.display()))
            })? {
                pairs.extend(run);
            }
            drop(reader);
            // Restore must not consume the checkpoint: re-defuse
            // delete-on-drop.
            file.keep();
            values.push((ck_name, Relation::from_pairs(pairs)));
        }
        trainer.steps = steps;
        Ok((trainer, values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ClusterConfig;
    use crate::ml::gcn::{self, GcnConfig};
    use crate::util::Prng;

    fn gcn_setup(w: usize) -> (Session, ModelSpec, Relation, Relation) {
        let g = crate::data::graphs::power_law_graph("st", 40, 120, 8, 4, 0.5, 31);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 5,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(77);
        let (w1, w2) = gcn::init_params(&cfg, &mut rng);
        let sess = Session::new(ClusterConfig::new(w));
        sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
            .unwrap();
        sess.register("Node", &["id"], &g.feats).unwrap();
        sess.register("Y", &["id"], &g.labels).unwrap();
        let spec = ModelSpec::new(q).param("W1", 1).param("W2", 1);
        (sess, spec, w1, w2)
    }

    #[test]
    fn named_steps_learn_and_accumulate_stats() {
        let (sess, spec, mut w1, mut w2) = gcn_setup(2);
        let mut trainer = sess.trainer(spec).unwrap();
        let base = sess.stats();
        let mut losses = Vec::new();
        for _ in 0..3 {
            let step = trainer
                .step(&[("W1", &w1), ("W2", &w2)])
                .unwrap();
            assert_eq!(step.grads.len(), 2);
            assert!(step.grad("W1").is_some() && step.grad("W2").is_some());
            for (name, grel) in &step.grads {
                let target = if name == "W1" { &mut w1 } else { &mut w2 };
                for kv in target.iter_mut() {
                    if let Some(gv) = grel.get(&kv.0) {
                        let mut d = gv.clone();
                        d.scale_assign(-0.1);
                        kv.1.add_assign(&d);
                    }
                }
            }
            losses.push(step.loss);
        }
        assert_eq!(trainer.steps(), 3);
        assert!(losses[2] < losses[0], "no learning: {losses:?}");
        let after = sess.stats();
        assert!(after.stages > base.stages);
        // Data moved only at registration; steps re-home parameters only.
        assert!(after.bytes_ingested > base.bytes_ingested);
    }

    fn assert_bitwise(a: &Relation, b: &Relation, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: tuple count");
        for ((ka, va), (kb, vb)) in a.pairs().iter().zip(b.pairs()) {
            assert_eq!(ka, kb, "{what}: key order");
            assert_eq!(va.shape(), vb.shape(), "{what}: shape at {ka}");
            for (x, y) in va.data().iter().zip(vb.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: bits at {ka}");
            }
        }
    }

    #[test]
    fn checkpoint_restore_round_trips_bitwise() {
        let (sess, spec, mut w1, mut w2) = gcn_setup(2);
        let mut trainer = sess.trainer(spec.clone()).unwrap();
        let step = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        for (name, grel) in &step.grads {
            let target = if name == "W1" { &mut w1 } else { &mut w2 };
            for kv in target.iter_mut() {
                if let Some(gv) = grel.get(&kv.0) {
                    let mut d = gv.clone();
                    d.scale_assign(-0.1);
                    kv.1.add_assign(&d);
                }
            }
        }
        let dir = std::env::temp_dir().join(format!(
            "relad-ckpt-unit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let total = trainer
            .checkpoint(&dir, &[("W1", &w1), ("W2", &w2)])
            .unwrap();
        assert!(total > 0);
        assert!(
            sess.stats().checkpoint_bytes >= total,
            "checkpoint bytes not charged to session stats"
        );
        let (restored, values) = sess.restore_trainer(&dir, spec.clone()).unwrap();
        assert_eq!(restored.steps(), 1, "step counter lost");
        assert_eq!(values.len(), 2);
        assert_eq!(values[0].0, "W1");
        assert_eq!(values[1].0, "W2");
        assert_bitwise(&values[0].1, &w1, "W1");
        assert_bitwise(&values[1].1, &w2, "W2");
        // Restore leaves the checkpoint intact: a second restore works.
        let (again, _) = sess.restore_trainer(&dir, spec.clone()).unwrap();
        assert_eq!(again.steps(), 1);
        // A mismatched spec (different parameter layout) is a typed
        // rejection — a checkpoint never silently rebinds.
        let wrong = ModelSpec::new(trainer.compiled().fwd.clone())
            .param_with_layout("W1", 1, SlotLayout::HashFull)
            .param("W2", 1);
        assert!(matches!(
            sess.restore_trainer(&dir, wrong),
            Err(SessionError::Invalid(_))
        ));
        // A mismatched cluster shape likewise.
        let (sess3, _spec3, _, _) = gcn_setup(3);
        let err = sess3.restore_trainer(&dir, spec).unwrap_err();
        assert!(matches!(err, SessionError::Invalid(_)), "got {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A `⟨id⟩` key the labels table does not hold yet.
    fn unlabeled_id(sess: &Session) -> crate::ra::Key {
        let head = sess.table("Y").unwrap();
        (0..10_000i64)
            .map(crate::ra::Key::k1)
            .find(|k| !head.shards.iter().any(|s| s.contains(k)))
            .expect("an unlabeled id exists")
    }

    #[test]
    fn steps_consume_catalog_deltas_without_reingest() {
        let (sess, spec, w1, w2) = gcn_setup(2);
        let mut trainer = sess.trainer(spec.clone()).unwrap();
        let step1 = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        // Stream a new labeled node into Y between steps.
        let k = unlabeled_id(&sess);
        let mut oh = crate::ra::Chunk::zeros(1, 4);
        oh.set(0, 2, 1.0);
        sess.insert("Y", vec![(k, oh)]).unwrap();
        let step2 = trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        // The refresh swapped heads by handle: the step itself charged
        // exactly the same ingest as before (parameter re-homing only).
        assert_eq!(step1.stats.bytes_ingested, step2.stats.bytes_ingested);
        assert!(sess.stats().delta_rows_applied >= 2, "insert + replay");
        // Bitwise oracle: a trainer compiled fresh against the updated
        // catalog takes the identical step.
        let mut fresh = sess.trainer(spec).unwrap();
        let want = fresh.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        assert_eq!(step2.loss.to_bits(), want.loss.to_bits());
        for ((na, ga), (nb, gb)) in step2.grads.iter().zip(&want.grads) {
            assert_eq!(na, nb);
            assert_bitwise(ga, gb, na);
        }
    }

    #[test]
    fn stale_data_slots_refuse_step_and_restore() {
        let (sess, spec, w1, w2) = gcn_setup(2);
        let mut trainer = sess.trainer(spec.clone()).unwrap();
        trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "relad-ckpt-stale-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        trainer
            .checkpoint(&dir, &[("W1", &w1), ("W2", &w2)])
            .unwrap();
        // Restore path: the catalog advanced past the checkpointed epoch.
        let k = unlabeled_id(&sess);
        let mut oh = crate::ra::Chunk::zeros(1, 4);
        oh.set(0, 1, 1.0);
        sess.insert("Y", vec![(k, oh)]).unwrap();
        assert!(matches!(
            sess.restore_trainer(&dir, spec.clone()),
            Err(SessionError::StaleEpoch { .. })
        ));
        // Step path: drop + re-register mints a new generation — the
        // live trainer's binds are stale; rebind() is the escape hatch.
        let y = sess.table("Y").unwrap().gather_in(None);
        sess.drop_table("Y").unwrap();
        sess.register("Y", &["id"], &y).unwrap();
        assert!(matches!(
            trainer.step(&[("W1", &w1), ("W2", &w2)]),
            Err(SessionError::StaleEpoch { .. })
        ));
        trainer.rebind().unwrap();
        trainer.step(&[("W1", &w1), ("W2", &w2)]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_param_and_missing_value_are_typed() {
        let (sess, spec, w1, w2) = gcn_setup(1);
        // Unknown parameter name at compile time.
        let bad = ModelSpec::new(sess.trainer(spec.clone()).unwrap().compiled().fwd.clone())
            .param("Wx", 1);
        assert!(matches!(
            sess.trainer(bad),
            Err(SessionError::UnknownTable(_))
        ));
        // Missing parameter value at step time.
        let mut trainer = sess.trainer(spec).unwrap();
        assert!(matches!(
            trainer.step(&[("W1", &w1)]),
            Err(SessionError::Invalid(_))
        ));
        // Non-parameter name supplied.
        assert!(matches!(
            trainer.step(&[("W1", &w1), ("W2", &w2), ("Edge", &w1)]),
            Err(SessionError::Invalid(_))
        ));
    }
}
