//! Distributed training through the session: a [`ModelSpec`] names the
//! parameter slots of a loss query, [`Session::trainer`] compiles it
//! against the catalog (data slots bind to registered tables by scan
//! name), and [`SessionTrainer::step`] runs taped forward + generated
//! backward on the session pool, returning *named* gradients and
//! accumulating per-step [`ExecStats`] on the session.
//!
//! This subsumes the deprecated `DistTrainer::new` →
//! `pipeline(layouts)` → `step_in(pool, …)` dance:
//!
//! * slots are addressed by **name** (the forward query's `TableScan`
//!   names), not by positional index — a reordered slot list cannot
//!   silently swap a parameter for a data table;
//! * the session catalog *is* the partition cache: data tables are
//!   placed once at registration and reused every step (zero
//!   re-partitioning, the `TrainPipeline` guarantee);
//! * the session pool serves every step — `for_worker` runs once per
//!   worker per session, however many steps the loop takes.

use super::{Session, SessionError};
use crate::dist::{ExecStats, PartitionedRelation};
use crate::ml::train::step_core;
use crate::ml::{DistTrainer, SlotLayout};
use crate::ra::expr::Query;
use crate::ra::Relation;

/// One parameter slot declaration: scan name, key arity, cluster layout.
#[derive(Clone, Debug)]
struct ParamSpec {
    name: String,
    arity: usize,
    layout: SlotLayout,
}

/// What to train: a loss query plus its named parameter slots. Every
/// other input slot is a *data* slot and binds to the session table
/// registered under the same name as its `TableScan`.
///
/// ```
/// use relad::ml::gcn::{self, GcnConfig};
/// use relad::session::ModelSpec;
///
/// let cfg = GcnConfig { feat_dim: 8, hidden: 8, n_labels: 4, dropout: None, seed: 1 };
/// let spec = ModelSpec::new(gcn::loss_query(&cfg, 10))
///     .param("W1", 1)
///     .param("W2", 1);
/// assert_eq!(spec.param_names(), ["W1", "W2"]);
/// ```
#[derive(Clone)]
pub struct ModelSpec {
    query: Query,
    params: Vec<ParamSpec>,
}

impl ModelSpec {
    pub fn new(query: Query) -> ModelSpec {
        ModelSpec {
            query,
            params: Vec::new(),
        }
    }

    /// Declare the scan named `name` (key width `arity`) a trainable
    /// parameter, replicated onto every worker (the usual layout for
    /// weight tables — the optimizer delta must reach all shards).
    pub fn param(self, name: &str, arity: usize) -> ModelSpec {
        self.param_with_layout(name, arity, SlotLayout::Replicated)
    }

    /// As [`param`](Self::param) with an explicit layout (e.g. large
    /// factor matrices hash-partitioned instead of replicated).
    pub fn param_with_layout(mut self, name: &str, arity: usize, layout: SlotLayout) -> ModelSpec {
        self.params.push(ParamSpec {
            name: name.to_string(),
            arity,
            layout,
        });
        self
    }

    /// Declared parameter names, in declaration order.
    pub fn param_names(&self) -> Vec<&str> {
        self.params.iter().map(|p| p.name.as_str()).collect()
    }
}

/// One training step's outputs, with gradients addressed by parameter
/// name (the session analogue of `ml::StepResult`).
pub struct NamedStep {
    pub loss: f32,
    /// `(parameter name, gathered gradient relation)` in [`ModelSpec`]
    /// declaration order.
    pub grads: Vec<(String, Relation)>,
    /// This step's execution stats (also merged into the session total).
    pub stats: ExecStats,
}

impl NamedStep {
    /// The gradient of one named parameter, if it was requested.
    pub fn grad(&self, name: &str) -> Option<&Relation> {
        self.grads
            .iter()
            .find_map(|(n, g)| (n == name).then_some(g))
    }
}

/// A compiled training loop bound to a session: forward + generated
/// backward share the session pool, data tables come from the catalog
/// (placed once), and parameters are re-homed each step. Built by
/// [`Session::trainer`].
pub struct SessionTrainer<'s> {
    sess: &'s Session,
    trainer: DistTrainer,
    /// Catalog table name per forward input slot (params + data).
    slot_names: Vec<String>,
    /// `(slot, declared key arity, layout)` of each parameter, in
    /// declaration order.
    param_slots: Vec<(usize, usize, SlotLayout)>,
    /// Cached placements for data slots (`None` at parameter slots) —
    /// handle copies of the catalog partitions, snapshotted at compile.
    data: Vec<Option<PartitionedRelation>>,
    steps: u64,
}

impl<'s> SessionTrainer<'s> {
    pub(crate) fn compile(sess: &'s Session, spec: ModelSpec) -> Result<Self, SessionError> {
        let slot_names = super::scan_names(&spec.query)?;
        let n = slot_names.len();
        let mut param_slots = Vec::with_capacity(spec.params.len());
        let mut arities = vec![0usize; n];
        let mut data: Vec<Option<PartitionedRelation>> = vec![None; n];
        for p in &spec.params {
            let slot = slot_names
                .iter()
                .position(|s| *s == p.name)
                .ok_or_else(|| SessionError::UnknownTable(p.name.clone()))?;
            if param_slots.iter().any(|&(s, _, _)| s == slot) {
                return Err(SessionError::Invalid(format!(
                    "parameter {} declared twice",
                    p.name
                )));
            }
            arities[slot] = p.arity;
            param_slots.push((slot, p.arity, p.layout.clone()));
        }
        for (slot, name) in slot_names.iter().enumerate() {
            if param_slots.iter().any(|&(s, _, _)| s == slot) {
                continue;
            }
            // Data slots bind to catalog tables by scan name.
            let part = sess
                .table(name)
                .ok_or_else(|| SessionError::UnknownTable(name.clone()))?;
            arities[slot] = sess.table_arity(name).unwrap_or(0);
            data[slot] = Some(part);
        }
        let wrt: Vec<usize> = param_slots.iter().map(|&(s, _, _)| s).collect();
        let trainer = DistTrainer::new(spec.query, &arities, &wrt)
            .map_err(|e| SessionError::NotDifferentiable(format!("{e:#}")))?;
        Ok(SessionTrainer {
            sess,
            trainer,
            slot_names,
            param_slots,
            data,
            steps: 0,
        })
    }

    /// The compiled forward/backward pair (e.g. to inspect the generated
    /// backward query).
    pub fn compiled(&self) -> &DistTrainer {
        &self.trainer
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Re-snapshot the data slots from the session catalog (call after
    /// re-registering a table, e.g. a new mini-batch sample).
    pub fn rebind(&mut self) -> Result<(), SessionError> {
        for (slot, name) in self.slot_names.iter().enumerate() {
            if self.param_slots.iter().any(|&(s, _, _)| s == slot) {
                continue;
            }
            self.data[slot] = Some(
                self.sess
                    .table(name)
                    .ok_or_else(|| SessionError::UnknownTable(name.clone()))?,
            );
        }
        Ok(())
    }

    /// One training step. `params` supplies the current value of every
    /// declared parameter by name (any order); data slots are served from
    /// the catalog snapshot. Parameters are re-homed under their layout
    /// (their values change every step) and the ingest is charged to the
    /// step's stats; data moves zero bytes.
    pub fn step(&mut self, params: &[(&str, &Relation)]) -> Result<NamedStep, SessionError> {
        let w = self.sess.workers();
        let cfg = self.sess.cfg();
        let mut placed: Vec<Option<PartitionedRelation>> = self.data.clone();
        let mut ingest = 0u64;
        let mut ingest_s = 0.0f64;
        for &(slot, arity, ref layout) in &self.param_slots {
            let name = &self.slot_names[slot];
            let (_, rel) = params
                .iter()
                .find(|(n, _)| n == name)
                .ok_or_else(|| {
                    SessionError::Invalid(format!("no value supplied for parameter {name}"))
                })?;
            super::check_arity(name, arity, rel.key_arity())?;
            let bytes = layout.ingest_bytes(rel.nbytes() as u64, w);
            ingest += bytes;
            ingest_s += layout.ingest_time(&cfg.net, bytes, w);
            placed[slot] = Some(layout.place(rel, w));
        }
        for (n, _) in params {
            if !self
                .param_slots
                .iter()
                .any(|&(s, _, _)| self.slot_names[s] == *n)
            {
                return Err(SessionError::Invalid(format!(
                    "{n} is not a declared parameter of this trainer"
                )));
            }
        }
        let inputs: Vec<PartitionedRelation> = placed
            .into_iter()
            .map(|p| p.expect("every slot is a param or bound data"))
            .collect();
        let res = step_core(
            &self.trainer,
            &inputs,
            cfg,
            self.sess.backend(),
            self.sess.pool(),
        )?;
        let mut stats = res.stats;
        stats.bytes_ingested += ingest;
        stats.net_s += ingest_s;
        stats.virtual_time_s += ingest_s;
        self.sess.merge_stats(&stats);
        self.steps += 1;
        // Gradients arrive slot-addressed from the core; hand them back
        // name-addressed in declaration order, *moving* each relation
        // (no gradient is ever deep-copied).
        let mut slot_grads = res.grads;
        let mut grads = Vec::with_capacity(self.param_slots.len());
        for &(slot, _, _) in &self.param_slots {
            let idx = slot_grads
                .iter()
                .position(|(s, _)| *s == slot)
                .ok_or_else(|| {
                    SessionError::Invalid(format!(
                        "backward plan produced no gradient for slot {slot}"
                    ))
                })?;
            let (_, g) = slot_grads.swap_remove(idx);
            grads.push((self.slot_names[slot].clone(), g));
        }
        Ok(NamedStep {
            loss: res.loss,
            grads,
            stats,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ClusterConfig;
    use crate::ml::gcn::{self, GcnConfig};
    use crate::util::Prng;

    fn gcn_setup(w: usize) -> (Session, ModelSpec, Relation, Relation) {
        let g = crate::data::graphs::power_law_graph("st", 40, 120, 8, 4, 0.5, 31);
        let cfg = GcnConfig {
            feat_dim: 8,
            hidden: 8,
            n_labels: 4,
            dropout: None,
            seed: 5,
        };
        let q = gcn::loss_query(&cfg, g.labels.len());
        let mut rng = Prng::new(77);
        let (w1, w2) = gcn::init_params(&cfg, &mut rng);
        let mut sess = Session::new(ClusterConfig::new(w));
        sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
            .unwrap();
        sess.register("Node", &["id"], &g.feats).unwrap();
        sess.register("Y", &["id"], &g.labels).unwrap();
        let spec = ModelSpec::new(q).param("W1", 1).param("W2", 1);
        (sess, spec, w1, w2)
    }

    #[test]
    fn named_steps_learn_and_accumulate_stats() {
        let (sess, spec, mut w1, mut w2) = gcn_setup(2);
        let mut trainer = sess.trainer(spec).unwrap();
        let base = sess.stats();
        let mut losses = Vec::new();
        for _ in 0..3 {
            let step = trainer
                .step(&[("W1", &w1), ("W2", &w2)])
                .unwrap();
            assert_eq!(step.grads.len(), 2);
            assert!(step.grad("W1").is_some() && step.grad("W2").is_some());
            for (name, grel) in &step.grads {
                let target = if name == "W1" { &mut w1 } else { &mut w2 };
                for kv in target.iter_mut() {
                    if let Some(gv) = grel.get(&kv.0) {
                        let mut d = gv.clone();
                        d.scale_assign(-0.1);
                        kv.1.add_assign(&d);
                    }
                }
            }
            losses.push(step.loss);
        }
        assert_eq!(trainer.steps(), 3);
        assert!(losses[2] < losses[0], "no learning: {losses:?}");
        let after = sess.stats();
        assert!(after.stages > base.stages);
        // Data moved only at registration; steps re-home parameters only.
        assert!(after.bytes_ingested > base.bytes_ingested);
    }

    #[test]
    fn unknown_param_and_missing_value_are_typed() {
        let (sess, spec, w1, w2) = gcn_setup(1);
        // Unknown parameter name at compile time.
        let bad = ModelSpec::new(sess.trainer(spec.clone()).unwrap().compiled().fwd.clone())
            .param("Wx", 1);
        assert!(matches!(
            sess.trainer(bad),
            Err(SessionError::UnknownTable(_))
        ));
        // Missing parameter value at step time.
        let mut trainer = sess.trainer(spec).unwrap();
        assert!(matches!(
            trainer.step(&[("W1", &w1)]),
            Err(SessionError::Invalid(_))
        ));
        // Non-parameter name supplied.
        assert!(matches!(
            trainer.step(&[("W1", &w1), ("W2", &w2), ("Edge", &w1)]),
            Err(SessionError::Invalid(_))
        ));
    }
}
