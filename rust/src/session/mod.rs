//! The stateful front door of the engine: one [`Session`] owns the
//! cluster, the catalog, and every execution.
//!
//! The paper's pitch is that a *relational engine* runs auto-differentiated
//! ML at scale: you hand it relations and a relational computation, and it
//! plans, differentiates, and executes. A `Session` is that engine
//! surface. Constructed from a [`ClusterConfig`], it owns
//!
//! * the persistent [`WorkerPool`] (built once, with one `KernelBackend`
//!   instance minted per worker via `for_worker` — every query, gradient,
//!   and training step of the session runs on the same `w` threads),
//! * a named-table **catalog** of [`PartitionedRelation`]s
//!   ([`Session::register`] / [`Session::register_partitioned`] /
//!   [`Session::drop_table`], each entry carrying key-column names, arity,
//!   and partitioning metadata). Registered tables are not static:
//!   [`Session::insert`] / [`Session::delete`] apply ±1-signed delta
//!   batches that inherit the base partitioning (new rows route to their
//!   owning shard, untouched shards keep their `Arc` handles — no
//!   reshuffle on ingest) and advance the table's **epoch**; memoized
//!   [`Frame`]s replay only the new epochs on re-collect (incremental
//!   view maintenance, §7 of ARCHITECTURE),
//! * accumulated [`ExecStats`] across everything the session executed.
//!
//! Execution is unified behind two lazy entry points returning a
//! [`Frame`] handle:
//!
//! * [`Session::sql`] parses a SQL statement against the catalog,
//! * [`Session::query`] binds a functional-RA [`Query`] whose `TableScan`
//!   names resolve against the catalog,
//!
//! and [`Frame::collect`] executes, [`Frame::explain`] reports the join
//! strategy and shuffle plan per stage, and [`Frame::grad`] runs the taped
//! forward plus the *generated backward query* through the same pool.
//!
//! Sessions grace-spill through **real temp files** when asked to:
//! under a budgeted `MemPolicy::Spill` configuration, any query or
//! training step whose per-worker join working set exceeds the budget
//! writes its build side to disk in grace runs and streams them back
//! pass by pass (`ClusterConfig::spill_dir` picks the device;
//! [`Session::spill_root`] exposes the scratch tree), completing where
//! `MemPolicy::Fail` reports OOM; the measured traffic lands in
//! `ExecStats::spill_bytes_written`/`spill_bytes_read` on
//! [`Session::stats`]. Results are bitwise identical to the same plan
//! run fully in memory.
//! [`Session::trainer`] compiles a [`ModelSpec`] (named — not positional —
//! parameter slots) into a [`SessionTrainer`] for full training loops.
//!
//! Every error flows through one typed [`SessionError`] built on
//! [`DistError`]; user input never panics the engine.
//!
//! # Migration note (from the deprecated free functions)
//!
//! | pre-session | session |
//! |---|---|
//! | `dist_eval(&q, inputs, &cfg, &be)` | `sess.query(&q)?.collect()` |
//! | `dist_eval_tape*` / `dist_eval_multi*` | `sess.query(&q)?.grad("W")` |
//! | `DistTrainer::new` + `pipeline(layouts)` + `step_in(pool, …)` | `sess.trainer(ModelSpec::new(q).param("W", 1))?` then `t.step(&[("W", &w)])` |
//!
//! The deprecated wrappers delegate to the same execution core the
//! session drives, so results are identical; the session additionally
//! keeps the pool warm across calls and the catalog partitions cached.
//!
//! # Example
//!
//! ```
//! use relad::dist::ClusterConfig;
//! use relad::ra::{Chunk, Key, Relation};
//! use relad::session::Session;
//!
//! # fn main() -> Result<(), relad::session::SessionError> {
//! let sess = Session::new(ClusterConfig::new(2));
//!
//! // Register two 2×2-blocked matrices as tensor-relation tables.
//! let mut a = Relation::new();
//! let mut b = Relation::new();
//! for i in 0..2i64 {
//!     for k in 0..2i64 {
//!         a.insert(Key::k2(i, k), Chunk::filled(4, 4, 1.0));
//!         b.insert(Key::k2(k, i), Chunk::filled(4, 4, 0.5));
//!     }
//! }
//! sess.register("A", &["row", "col"], &a)?;
//! sess.register("B", &["row", "col"], &b)?;
//!
//! // The paper's blocked matmul, straight from SQL.
//! let frame = sess.sql(
//!     "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
//!      FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
//! )?;
//! let z = frame.collect()?;
//! assert_eq!(z.len(), 4);
//!
//! // The gradient of the same computation w.r.t. B — itself a generated
//! // relational query, executed on the same pool.
//! let db = frame.grad("B")?;
//! assert_eq!(db.len(), 4);
//! assert!(sess.stats().stages > 0);
//! # Ok(())
//! # }
//! ```

mod frame;
mod trainer;

pub use frame::Frame;
pub use trainer::{ModelSpec, NamedStep, SessionTrainer};

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::dist::delta::{DeltaCtx, NodeStatus};
use crate::dist::exec::{eval_tape_delta, StageTrace};
use crate::dist::{
    shuffle, ClusterConfig, DistError, DistTape, ExecStats, PartitionedRelation, Partitioning,
    WorkerPool,
};
use crate::kernels::{KernelBackend, NativeBackend};
use crate::ml::SlotLayout;
use crate::ra::eval::subkey;
use crate::ra::expr::{Op, Query};
use crate::ra::{Chunk, Key, Relation};
use crate::sql;
use crate::util::{FxHashMap, FxHashSet, Prng};

/// Errors from the session surface — one typed enum for everything user
/// input can trigger, built on [`DistError`] for execution failures (the
/// `Oom` cells of the paper's tables arrive as
/// `SessionError::Exec(DistError::Oom { .. })`).
#[derive(Debug)]
pub enum SessionError {
    /// A table name (in SQL, a query's `TableScan`, or a `grad`/`drop`
    /// target) is not in the session catalog.
    UnknownTable(String),
    /// `register*` with a name the catalog already holds.
    DuplicateTable(String),
    /// A relation's key width disagrees with its declared key columns
    /// (or a query binds a table at the wrong arity).
    ArityMismatch {
        table: String,
        expected: usize,
        got: usize,
    },
    /// `Frame::grad` on a computation the relational autodiff cannot
    /// differentiate (e.g. `Σ` with `⊕ = max`, or a kernel with no vjp
    /// for the requested operand).
    NotDifferentiable(String),
    /// SQL lexing/parsing/lowering failed.
    Sql(anyhow::Error),
    /// Invalid request against this session's configuration (worker-count
    /// mismatch, missing parameter value, …).
    Invalid(String),
    /// A memoized frame or a restored trainer is bound to catalog state
    /// that no longer exists: the table was dropped and re-registered
    /// (its identity generation changed), or a checkpoint's recorded
    /// update epoch disagrees with the catalog. Refusing is the safe
    /// answer — replaying deltas across an identity change would silently
    /// read unrelated data.
    StaleEpoch {
        table: String,
        /// The generation/epoch the frame or checkpoint was bound at.
        bound: u64,
        /// What the catalog holds now.
        current: u64,
    },
    /// Execution failed — including worker OOM under `MemPolicy::Fail`.
    Exec(DistError),
}

impl fmt::Display for SessionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionError::UnknownTable(n) => write!(f, "unknown table {n}"),
            SessionError::DuplicateTable(n) => {
                write!(f, "table {n} is already registered (drop_table first)")
            }
            SessionError::ArityMismatch {
                table,
                expected,
                got,
            } => write!(
                f,
                "table {table}: declared {expected} key column(s), relation keys have {got}"
            ),
            SessionError::NotDifferentiable(why) => {
                write!(f, "query is not differentiable: {why}")
            }
            SessionError::Sql(e) => write!(f, "SQL error: {e}"),
            SessionError::Invalid(why) => write!(f, "invalid request: {why}"),
            SessionError::StaleEpoch {
                table,
                bound,
                current,
            } => write!(
                f,
                "table {table} is stale: bound at {bound}, catalog at {current} \
                 (a dropped-and-reregistered table cannot serve memoized state)"
            ),
            SessionError::Exec(e) => write!(f, "execution failed: {e}"),
        }
    }
}

impl std::error::Error for SessionError {}

impl From<DistError> for SessionError {
    fn from(e: DistError) -> SessionError {
        SessionError::Exec(e)
    }
}

/// One applied update batch: the ±1-signed tuples, placed by the base
/// table's partitioning (inserts carry the new tuples, deletes the
/// removed ones — no reshuffle on ingest).
struct DeltaBatch {
    /// `+1` for an insert batch, `-1` for a delete batch.
    sign: i8,
    /// The batch's tuples, routed exactly like the base shards.
    part: PartitionedRelation,
    /// Tuples in the batch.
    rows: u64,
}

/// One catalog entry: a named, already-partitioned tensor-relation.
struct Table {
    name: String,
    /// Ordered key column names (the SQL frontend's schema); the value
    /// column is always `<table>.val`.
    key_cols: Vec<String>,
    /// The merged head: base shards plus every applied delta batch.
    /// Untouched shards keep their original `Arc` handles across
    /// updates, so a frame can tell — by pointer identity — which shards
    /// never changed.
    part: PartitionedRelation,
    /// Identity generation, unique across the session's lifetime: a
    /// dropped-and-reregistered table gets a *new* generation, which is
    /// how memoized frames distinguish "same table, more epochs" from
    /// "different table wearing the same name" ([`SessionError::StaleEpoch`]).
    gen: u64,
    /// Update epoch: 0 at registration, +1 per applied insert/delete
    /// batch. Batch `i` of `deltas` produced epoch `i + 1`.
    epoch: u64,
    /// Total rows across all applied delta batches.
    delta_rows: u64,
    /// Every applied batch since registration, in epoch order — the
    /// replay log frames consult to reach the current epoch.
    deltas: Vec<DeltaBatch>,
}

/// Metadata row returned by [`Session::tables`].
#[derive(Clone, Debug)]
pub struct TableInfo {
    pub name: String,
    pub key_cols: Vec<String>,
    /// Key width (= `key_cols.len()`).
    pub arity: usize,
    /// Where the tuples live ([`Partitioning`], rendered).
    pub partitioning: String,
    /// Distinct tuples.
    pub rows: usize,
    /// Payload bytes of one replica.
    pub nbytes: u64,
    /// Update epoch: 0 at registration, +1 per applied
    /// [`Session::insert`]/[`Session::delete`] batch.
    pub epoch: u64,
    /// Total rows across all delta batches applied since registration.
    pub delta_rows: u64,
}

/// The shared, thread-safe heart of a session: cluster config, kernel
/// backend, the persistent worker pool, the named-table catalog, and the
/// accumulated execution statistics. Since the serving layer (PR 9) this
/// state is `Send + Sync` — the catalog and stats live behind [`Mutex`]es
/// (they were `RefCell`s when `Session` was strictly single-owner) so one
/// state can back many concurrent [`crate::serve::Client`] handles. A
/// plain [`Session`] is a thin single-owner wrapper over one `Arc` of
/// this; [`crate::serve::Engine`] holds the same `Arc` and mints cheap
/// shared views of it.
pub(crate) struct SessionState {
    cfg: ClusterConfig,
    backend: Box<dyn KernelBackend + Send + Sync>,
    /// The state-lifetime worker pool: built once at construction (iff
    /// the configuration threads on this host), serving every query,
    /// gradient, and training step of every view sharing this state.
    /// `Arc` so the pool's multi-owner dispatch contract
    /// ([`WorkerPool`] module docs) is available to callers that hold
    /// their own handle.
    pool: Option<Arc<WorkerPool>>,
    /// The catalog. Lock-protected so [`Session::insert`] /
    /// [`Session::delete`] (and `register*`/`drop_table`) can run while
    /// lazy [`Frame`]s hold a shared borrow of the session — and so
    /// concurrent serving clients share it safely.
    tables: Mutex<Vec<Table>>,
    /// Source of table identity generations (see [`Table::gen`]).
    next_gen: AtomicU64,
    /// Accumulated across every execution charged to this state.
    stats: Mutex<ExecStats>,
}

/// The stateful engine session — catalog + pool + unified execution.
/// See the [module docs](self) for the full tour and a runnable example.
///
/// A `Session` is a thin single-owner wrapper over the shared
/// [`SessionState`]; the concurrent front door ([`crate::serve::Engine`])
/// shares the same state across many clients. `Session` itself is
/// `Send + Sync` — lazy [`Frame`]s and [`SessionTrainer`]s borrow it and
/// stay single-threaded, but the session handle can move across threads.
pub struct Session {
    st: Arc<SessionState>,
}

impl Session {
    /// A session on the native kernel backend.
    pub fn new(cfg: ClusterConfig) -> Session {
        Session::with_backend(cfg, Box::new(NativeBackend))
    }

    /// A session on a caller-chosen backend (e.g. from
    /// `kernels::registry::make_backend`). The pool — and the one
    /// backend instance per worker it mints via `for_worker` — is built
    /// here, once, for the session's whole lifetime.
    pub fn with_backend(
        cfg: ClusterConfig,
        backend: Box<dyn KernelBackend + Send + Sync>,
    ) -> Session {
        let pool = WorkerPool::maybe_new(&cfg, backend.as_ref()).map(Arc::new);
        Session {
            st: Arc::new(SessionState {
                cfg,
                backend,
                pool,
                tables: Mutex::new(Vec::new()),
                next_gen: AtomicU64::new(1),
                stats: Mutex::new(ExecStats::default()),
            }),
        }
    }

    /// Another single-owner view over the same shared state — same pool,
    /// same catalog, same accumulated stats. This is how the serving
    /// layer mints per-client views; it is deliberately not public
    /// `Clone` (a `Session` presents single-owner semantics; concurrent
    /// sharing goes through [`crate::serve::Engine`]).
    pub(crate) fn share(&self) -> Session {
        Session {
            st: Arc::clone(&self.st),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.st.cfg
    }

    pub fn workers(&self) -> usize {
        self.st.cfg.workers
    }

    pub fn backend_name(&self) -> &'static str {
        self.st.backend.name()
    }

    /// Root of the session's spill scratch tree, if this cluster shape
    /// reserved one (budgeted [`MemPolicy::Spill`](crate::dist::MemPolicy)
    /// with a pooled session). Worker subdirectories and run files appear
    /// under it only while a query actually runs out-of-core; the tree is
    /// removed when the session drops. Pool-less (serial) sessions spill
    /// into per-evaluation scratch instead, removed per run — either way
    /// `ClusterConfig::spill_dir` (or `$RELAD_SPILL_DIR`) picks the
    /// device the scratch lives on.
    pub fn spill_root(&self) -> Option<std::path::PathBuf> {
        self.st
            .pool
            .as_deref()
            .and_then(|p| p.spill_space())
            .map(|s| s.root().to_path_buf())
    }

    /// Register a relation as table `name`, hash-partitioned on the full
    /// key (the default layout for data tables).
    pub fn register(
        &self,
        name: &str,
        key_cols: &[&str],
        rel: &Relation,
    ) -> Result<(), SessionError> {
        self.register_with_layout(name, key_cols, rel, &SlotLayout::HashFull)
    }

    /// Register a relation under an explicit [`SlotLayout`] (replicate
    /// small/broadcast tables, hash-partition edges on the destination
    /// vertex, …).
    pub fn register_with_layout(
        &self,
        name: &str,
        key_cols: &[&str],
        rel: &Relation,
        layout: &SlotLayout,
    ) -> Result<(), SessionError> {
        self.check_new_name(name)?;
        check_arity(name, key_cols.len(), rel.key_arity())?;
        if let SlotLayout::HashOn(comps) = layout {
            if comps.iter().any(|&c| c >= key_cols.len()) {
                return Err(SessionError::Invalid(format!(
                    "table {name}: HashOn components {comps:?} out of range for arity {}",
                    key_cols.len()
                )));
            }
        }
        let w = self.st.cfg.workers;
        let mut part = layout.place(rel, w);
        // Ingest-time skew detection ([`ClusterConfig::skew_threshold`]):
        // annotate a hash-placed table whose sampled key-frequency head
        // crosses the threshold. Metadata only — shard placement is
        // untouched, so an annotated table holds bitwise the same shards
        // as its oblivious twin; the annotation just unlocks the skew
        // join strategies in `dist::exec::plan_join`.
        if let Some(thresh) = self.st.cfg.skew_threshold {
            if let Some(comps) = part.part.hash_comps().map(<[usize]>::to_vec) {
                let hot = detect_hot_keys(rel, &comps, thresh);
                if !hot.is_empty() {
                    self.st.stats.lock().unwrap().hot_keys_detected += hot.len() as u64;
                    part.part = Partitioning::SkewHash {
                        comps,
                        hot: hot.into(),
                    };
                }
            }
        }
        self.charge_ingest(layout.ingest_bytes(rel.nbytes() as u64, w), layout);
        self.push_table(name, key_cols, part);
        Ok(())
    }

    /// Register an already-partitioned relation (the caller controls the
    /// exact shard placement). The shard count must match the session's
    /// worker count.
    pub fn register_partitioned(
        &self,
        name: &str,
        key_cols: &[&str],
        part: PartitionedRelation,
    ) -> Result<(), SessionError> {
        self.check_new_name(name)?;
        if part.workers() != self.st.cfg.workers {
            return Err(SessionError::Invalid(format!(
                "table {name}: sharded across {} worker(s), session has {}",
                part.workers(),
                self.st.cfg.workers
            )));
        }
        let arity = part.key_arity();
        if !part.is_empty() {
            check_arity(name, key_cols.len(), Some(arity))?;
        }
        let layout = match &part.part {
            Partitioning::Replicated => SlotLayout::Replicated,
            _ => SlotLayout::HashFull,
        };
        self.charge_ingest(layout.ingest_bytes(part.nbytes(), self.st.cfg.workers), &layout);
        self.push_table(name, key_cols, part);
        Ok(())
    }

    /// Remove a table from the catalog. Frames bound before the drop keep
    /// their shard handles (`Arc`s) and stay executable against the
    /// frozen snapshot; if a table of the same name is *re-registered*,
    /// memoized frames refuse with [`SessionError::StaleEpoch`] instead
    /// of silently replaying deltas against an unrelated table (the new
    /// registration carries a new identity generation).
    pub fn drop_table(&self, name: &str) -> Result<(), SessionError> {
        let mut tables = self.st.tables.lock().unwrap();
        match tables.iter().position(|t| t.name == name) {
            Some(i) => {
                tables.remove(i);
                Ok(())
            }
            None => Err(SessionError::UnknownTable(name.to_string())),
        }
    }

    /// Apply an insert-only delta batch to a registered table: every key
    /// must be new (and appear once in the batch — validated before any
    /// shard is touched), rows route to the shard the base partitioning
    /// owns them on, and untouched shards keep their `Arc` handles, so
    /// ingest never reshuffles. Advances the table's epoch; memoized
    /// frames replay only the new epochs on their next collect/grad.
    ///
    /// Arbitrarily-partitioned tables refuse (`Invalid`): without a base
    /// placement rule there is nothing to route the delta by.
    pub fn insert(&self, name: &str, rows: Vec<(Key, Chunk)>) -> Result<(), SessionError> {
        if rows.is_empty() {
            return Err(SessionError::Invalid(format!(
                "insert into {name}: empty batch"
            )));
        }
        let w = self.st.cfg.workers;
        let mut tables = self.st.tables.lock().unwrap();
        let t = tables
            .iter_mut()
            .find(|t| t.name == name)
            .ok_or_else(|| SessionError::UnknownTable(name.to_string()))?;
        if matches!(t.part.part, Partitioning::Arbitrary) {
            return Err(SessionError::Invalid(format!(
                "table {name} is arbitrarily partitioned — a delta has no base placement \
                 to inherit"
            )));
        }
        let arity = t.key_cols.len();
        // Validate the whole batch before touching any shard: applying a
        // prefix of a bad batch would leave the epoch log inconsistent.
        let mut seen = FxHashSet::default();
        for (k, _) in &rows {
            if k.len() != arity {
                return Err(SessionError::ArityMismatch {
                    table: name.to_string(),
                    expected: arity,
                    got: k.len(),
                });
            }
            if !seen.insert(*k) {
                return Err(SessionError::Invalid(format!(
                    "insert into {name}: key {k} appears twice in the batch"
                )));
            }
            if t.part.shards.iter().any(|s| s.contains(k)) {
                return Err(SessionError::Invalid(format!(
                    "insert into {name}: key {k} is already present (delete it first)"
                )));
            }
        }
        // Route the batch exactly like the base partitioning.
        let mut delta_shards: Vec<Relation> = (0..w).map(|_| Relation::new()).collect();
        for (k, v) in &rows {
            match &t.part.part {
                // A skew-annotated table routes exactly like plain Hash —
                // the annotation changes join planning, never placement.
                Partitioning::Hash(comps) | Partitioning::SkewHash { comps, .. } => {
                    delta_shards[shuffle::owner(k, comps, w)].insert(*k, v.clone());
                }
                Partitioning::Replicated => {
                    for ds in delta_shards.iter_mut() {
                        ds.insert(*k, v.clone());
                    }
                }
                Partitioning::Arbitrary => unreachable!("refused above"),
            }
        }
        // Merge into the head: owning shards append the new rows in batch
        // order (bitwise-identical to re-partitioning the merged table
        // from scratch); the rest keep their handles.
        let mut new_shards = t.part.shards.clone();
        for (wi, ds) in delta_shards.iter().enumerate() {
            if ds.is_empty() {
                continue;
            }
            let mut merged = (*new_shards[wi]).clone();
            for (k, v) in ds.iter() {
                merged.insert(*k, v.clone());
            }
            new_shards[wi] = Arc::new(merged);
        }
        let nrows = rows.len() as u64;
        let batch = PartitionedRelation::from_shards(delta_shards, t.part.part.clone());
        let bytes = batch.nbytes();
        t.part = PartitionedRelation::from_shard_handles(new_shards, t.part.part.clone());
        t.epoch += 1;
        t.delta_rows += nrows;
        t.deltas.push(DeltaBatch {
            sign: 1,
            part: batch,
            rows: nrows,
        });
        drop(tables);
        let mut st = self.st.stats.lock().unwrap();
        st.delta_rows_applied += nrows;
        st.bytes_ingested += bytes;
        Ok(())
    }

    /// Apply a delete delta batch to a registered table: every key must
    /// be present (and appear once in the batch — validated before any
    /// shard is touched). Owning shards are rebuilt preserving survivor
    /// order; untouched shards keep their `Arc` handles. The removed
    /// tuples are kept as a −1-signed batch and the epoch advances;
    /// memoized frames fall back to full recompute from the merged head
    /// (bitwise-equal) since deletions cannot replay as a suffix.
    pub fn delete(&self, name: &str, keys: &[Key]) -> Result<(), SessionError> {
        if keys.is_empty() {
            return Err(SessionError::Invalid(format!(
                "delete from {name}: empty batch"
            )));
        }
        let w = self.st.cfg.workers;
        let mut tables = self.st.tables.lock().unwrap();
        let t = tables
            .iter_mut()
            .find(|t| t.name == name)
            .ok_or_else(|| SessionError::UnknownTable(name.to_string()))?;
        if matches!(t.part.part, Partitioning::Arbitrary) {
            return Err(SessionError::Invalid(format!(
                "table {name} is arbitrarily partitioned — a delta has no base placement \
                 to inherit"
            )));
        }
        let arity = t.key_cols.len();
        let mut seen = FxHashSet::default();
        for k in keys {
            if k.len() != arity {
                return Err(SessionError::ArityMismatch {
                    table: name.to_string(),
                    expected: arity,
                    got: k.len(),
                });
            }
            if !seen.insert(*k) {
                return Err(SessionError::Invalid(format!(
                    "delete from {name}: key {k} appears twice in the batch"
                )));
            }
            if !t.part.shards.iter().any(|s| s.contains(k)) {
                return Err(SessionError::Invalid(format!(
                    "delete from {name}: key {k} is not present"
                )));
            }
        }
        // Capture the removed tuples (the −1-signed batch) and rebuild
        // only the shards that lost rows, keeping survivor order.
        let mut delta_shards: Vec<Relation> = Vec::with_capacity(w);
        let mut new_shards = t.part.shards.clone();
        for wi in 0..w {
            let shard = &t.part.shards[wi];
            let mut gone = Relation::new();
            for (k, v) in shard.iter() {
                if seen.contains(k) {
                    gone.insert(*k, v.clone());
                }
            }
            if !gone.is_empty() {
                let mut kept = Relation::with_capacity(shard.len() - gone.len());
                for (k, v) in shard.iter() {
                    if !seen.contains(k) {
                        kept.insert(*k, v.clone());
                    }
                }
                new_shards[wi] = Arc::new(kept);
            }
            delta_shards.push(gone);
        }
        let nrows = keys.len() as u64;
        let batch = PartitionedRelation::from_shards(delta_shards, t.part.part.clone());
        t.part = PartitionedRelation::from_shard_handles(new_shards, t.part.part.clone());
        t.epoch += 1;
        t.delta_rows += nrows;
        t.deltas.push(DeltaBatch {
            sign: -1,
            part: batch,
            rows: nrows,
        });
        drop(tables);
        self.st.stats.lock().unwrap().delta_rows_applied += nrows;
        Ok(())
    }

    /// The signed delta batches applied to a table since registration,
    /// in epoch order (`+1` insert, `-1` delete), each placed by the
    /// base partitioning — catalog introspection for the delta log
    /// `Frame`s replay.
    pub fn table_deltas(&self, name: &str) -> Option<Vec<(i8, PartitionedRelation)>> {
        self.with_table(name, |t| {
            t.deltas.iter().map(|b| (b.sign, b.part.clone())).collect()
        })
    }

    /// Catalog metadata: one row per registered table, including its
    /// update epoch and cumulative delta-row count (both zero for a
    /// table that has only been registered).
    pub fn tables(&self) -> Vec<TableInfo> {
        self.st
            .tables
            .lock()
            .unwrap()
            .iter()
            .map(|t| TableInfo {
                name: t.name.clone(),
                key_cols: t.key_cols.clone(),
                arity: t.key_cols.len(),
                partitioning: format!("{:?}", t.part.part),
                rows: t.part.len(),
                nbytes: t.part.nbytes(),
                epoch: t.epoch,
                delta_rows: t.delta_rows,
            })
            .collect()
    }

    /// The partitioned relation behind a registered table (a handle
    /// copy of the current merged head), if present.
    pub fn table(&self, name: &str) -> Option<PartitionedRelation> {
        self.with_table(name, |t| t.part.clone())
    }

    /// Parse a SQL statement against the catalog into a lazy [`Frame`].
    /// Table names resolve through the session catalog; unknown names are
    /// a typed [`SessionError::UnknownTable`].
    pub fn sql(&self, statement: &str) -> Result<Frame<'_>, SessionError> {
        let stmt = sql::parse::parse(statement).map_err(SessionError::Sql)?;
        let (query, names) = self.lower_stmt(&stmt)?;
        self.bind(query, &names)
    }

    /// Lower a parsed statement against the catalog without assembling a
    /// frame: the compact [`Query`] plus its slot-ordered table names
    /// (slot `i` ↔ `names[i]`). The serving layer's plan cache stores
    /// exactly this pair, keyed on the statement's canonical fixpoint SQL.
    pub(crate) fn lower_stmt(
        &self,
        stmt: &sql::parse::SelectStmt,
    ) -> Result<(Query, Vec<String>), SessionError> {
        // Bind FROM tables to compact query slots in statement order
        // (duplicates collapse: a self-join scans one slot twice).
        let mut names: Vec<String> = Vec::new();
        for t in &stmt.tables {
            if self.with_table(t, |_| ()).is_none() {
                return Err(SessionError::UnknownTable(t.clone()));
            }
            if !names.contains(t) {
                names.push(t.clone());
            }
        }
        let mut catalog = sql::Catalog::default();
        for (slot, name) in names.iter().enumerate() {
            let key_cols = self
                .with_table(name, |t| t.key_cols.clone())
                .expect("checked above");
            let cols: Vec<&str> = key_cols.iter().map(|s| s.as_str()).collect();
            catalog = catalog.table(name, slot, &cols);
        }
        let query = sql::lower::lower(stmt, &catalog).map_err(SessionError::Sql)?;
        Ok((query, names))
    }

    /// Assemble a frame from an already-lowered query bound to `names`
    /// (slot `i` ↔ `names[i]`) — the plan-cache hit path, skipping parse
    /// and lowering entirely.
    pub(crate) fn bind_named(
        &self,
        query: Query,
        names: &[String],
    ) -> Result<Frame<'_>, SessionError> {
        self.bind(query, names)
    }

    /// One locked snapshot of `(generation, epoch)` per name (`None` for
    /// names the catalog does not hold), taken atomically across all of a
    /// query's tables — the serving layer's cache version vector.
    pub(crate) fn table_versions(&self, names: &[String]) -> Vec<Option<(u64, u64)>> {
        let tables = self.st.tables.lock().unwrap();
        names
            .iter()
            .map(|n| {
                tables
                    .iter()
                    .find(|t| &t.name == n)
                    .map(|t| (t.gen, t.epoch))
            })
            .collect()
    }

    /// Bind a functional-RA query to the catalog as a lazy [`Frame`]:
    /// every `TableScan`'s *name* resolves to the registered table of the
    /// same name (the session analogue of the positional input slices the
    /// deprecated `dist_eval*` functions took).
    pub fn query(&self, q: &Query) -> Result<Frame<'_>, SessionError> {
        let names = scan_names(q)?;
        self.bind(q.clone(), &names)
    }

    /// Compile a [`ModelSpec`] into a [`SessionTrainer`]: parameter slots
    /// are named, data slots bind to catalog tables by scan name, and
    /// every step runs on the session pool.
    pub fn trainer(&self, spec: ModelSpec) -> Result<SessionTrainer<'_>, SessionError> {
        SessionTrainer::compile(self, spec)
    }

    /// Execution statistics accumulated over everything this session ran
    /// (queries, explains, gradients, training steps, catalog ingest).
    pub fn stats(&self) -> ExecStats {
        *self.st.stats.lock().unwrap()
    }

    /// Zero the accumulated statistics (e.g. between bench phases).
    pub fn reset_stats(&self) {
        *self.st.stats.lock().unwrap() = ExecStats::default();
    }

    // ------------------------------------------------------------ internal

    /// Run `f` against the named catalog entry, if present (the catalog
    /// lives behind a lock, so references cannot escape).
    fn with_table<R>(&self, name: &str, f: impl FnOnce(&Table) -> R) -> Option<R> {
        let tables = self.st.tables.lock().unwrap();
        tables.iter().find(|t| t.name == name).map(f)
    }

    fn check_new_name(&self, name: &str) -> Result<(), SessionError> {
        if name.is_empty() {
            return Err(SessionError::Invalid("table name must be non-empty".into()));
        }
        if self.with_table(name, |_| ()).is_some() {
            return Err(SessionError::DuplicateTable(name.to_string()));
        }
        Ok(())
    }

    fn push_table(&self, name: &str, key_cols: &[&str], part: PartitionedRelation) {
        let gen = self.st.next_gen.fetch_add(1, Ordering::Relaxed);
        self.st.tables.lock().unwrap().push(Table {
            name: name.to_string(),
            key_cols: key_cols.iter().map(|s| s.to_string()).collect(),
            part,
            gen,
            epoch: 0,
            delta_rows: 0,
            deltas: Vec::new(),
        });
    }

    /// Charge the driver→workers scatter of a newly registered table to
    /// the session stats (the session-era home of `TrainPipeline`'s
    /// ingest accounting: data moves once, at registration).
    fn charge_ingest(&self, bytes: u64, layout: &SlotLayout) {
        let w = self.st.cfg.workers;
        let secs = layout.ingest_time(&self.st.cfg.net, bytes, w);
        let mut st = self.st.stats.lock().unwrap();
        st.bytes_ingested += bytes;
        st.net_s += secs;
        st.virtual_time_s += secs;
    }

    /// Assemble a frame: per-slot inputs + arities from the catalog, in
    /// `names` order (slot `i` ↔ `names[i]`).
    fn bind(&self, query: Query, names: &[String]) -> Result<Frame<'_>, SessionError> {
        if names.len() < query.n_slots {
            return Err(SessionError::Invalid(format!(
                "query has {} input slot(s), resolved {} table name(s)",
                query.n_slots,
                names.len()
            )));
        }
        let mut inputs = Vec::with_capacity(names.len());
        let mut arities = Vec::with_capacity(names.len());
        let mut binds = Vec::with_capacity(names.len());
        for name in names {
            let (part, arity, gen, epoch) = self
                .with_table(name, |t| (t.part.clone(), t.key_cols.len(), t.gen, t.epoch))
                .ok_or_else(|| SessionError::UnknownTable(name.clone()))?;
            inputs.push(part);
            arities.push(arity);
            binds.push((gen, epoch));
        }
        Ok(Frame::new(self, query, names.to_vec(), inputs, arities, binds))
    }

    /// Run a query on the session pool (the one execution path every
    /// frame shares), merging its stats into the session — with an
    /// optional factorized plan's Σ exchange hints and an optional delta
    /// context: when
    /// `delta` carries a previous tape and per-slot change descriptors,
    /// the executor reuses clean subtrees and replays insert-only
    /// suffixes instead of recomputing (see `dist::delta`). Returns the
    /// derived per-node change statuses alongside the tape.
    pub(crate) fn run_tape_delta(
        &self,
        q: &Query,
        inputs: &[PartitionedRelation],
        agg_exchange: &[(crate::ra::expr::NodeId, Vec<usize>)],
        trace: Option<&mut Vec<StageTrace>>,
        delta: Option<&DeltaCtx>,
    ) -> Result<(DistTape, ExecStats, Vec<NodeStatus>), SessionError> {
        let (tape, stats, statuses) = eval_tape_delta(
            q,
            inputs,
            &self.st.cfg,
            self.st.backend.as_ref(),
            self.st.pool.as_deref(),
            agg_exchange,
            trace,
            delta,
        )?;
        self.st.stats.lock().unwrap().merge(&stats);
        Ok((tape, stats, statuses))
    }

    /// The pool the communication steps (gathers) may use.
    pub(crate) fn comm_pool(&self) -> Option<&WorkerPool> {
        if self.st.cfg.parallel && self.st.cfg.parallel_comm {
            self.st.pool.as_deref()
        } else {
            None
        }
    }

    pub(crate) fn pool(&self) -> Option<&WorkerPool> {
        self.st.pool.as_deref()
    }

    pub(crate) fn backend(&self) -> &dyn KernelBackend {
        self.st.backend.as_ref()
    }

    pub(crate) fn cfg(&self) -> &ClusterConfig {
        &self.st.cfg
    }

    pub(crate) fn merge_stats(&self, stats: &ExecStats) {
        self.st.stats.lock().unwrap().merge(stats);
    }

    pub(crate) fn table_arity(&self, name: &str) -> Option<usize> {
        self.with_table(name, |t| t.key_cols.len())
    }

    /// Everything a frame needs to refresh one bound slot: the current
    /// merged head, the table's identity generation, its update epoch,
    /// and the `(sign, rows)` summary of every delta batch since
    /// registration (batch `i` produced epoch `i + 1`).
    #[allow(clippy::type_complexity)]
    pub(crate) fn table_delta_state(
        &self,
        name: &str,
    ) -> Option<(PartitionedRelation, u64, u64, Vec<(i8, u64)>)> {
        self.with_table(name, |t| {
            (
                t.part.clone(),
                t.gen,
                t.epoch,
                t.deltas.iter().map(|b| (b.sign, b.rows)).collect(),
            )
        })
    }

    /// Charge delta rows replayed into a memoized frame or trainer slot
    /// (the catalog apply already charged its own rows at
    /// [`Session::insert`]/[`Session::delete`] time).
    pub(crate) fn charge_delta_rows(&self, rows: u64) {
        self.st.stats.lock().unwrap().delta_rows_applied += rows;
    }

    /// Charge one delta-gate fallback (a refused shape satisfied by full
    /// recompute).
    pub(crate) fn charge_delta_fallback(&self) {
        self.st.stats.lock().unwrap().delta_fallbacks += 1;
    }

    /// Per-name partitioning signature: the `Debug` rendering of each
    /// table's [`Partitioning`], hot-key annotation included (`None` for
    /// names the catalog does not hold). Part of the serving layer's
    /// plan-cache key, so a cached plan never outlives a layout or
    /// skew-annotation change.
    pub(crate) fn table_part_sigs(&self, names: &[String]) -> Vec<Option<String>> {
        let tables = self.st.tables.lock().unwrap();
        names
            .iter()
            .map(|n| {
                tables
                    .iter()
                    .find(|t| &t.name == n)
                    .map(|t| format!("{:?}", t.part.part))
            })
            .collect()
    }
}

/// Sampling cap for ingest-time heavy-hitter detection: tables at or
/// under this row count are counted exactly; larger tables are sampled
/// at this many fixed-seed rows.
const SKEW_SAMPLE_CAP: usize = 1024;

/// At most this many hot keys are recorded per table — past that the
/// head is no longer a head, and salting everything is just a shuffle
/// wearing a different name.
const SKEW_MAX_HOT: usize = 64;

/// Ingest-time heavy-hitter detection — the sampler behind
/// [`ClusterConfig::skew_threshold`]. Estimates the frequency of each
/// join-subkey value (`rel`'s keys projected to `comps`) and returns the
/// values whose sampled frequency strictly exceeds `threshold`, sorted.
///
/// Deterministic for fixed data: a table of at most 1024 rows is counted
/// exactly, a larger one is sampled at 1024 fixed-seed
/// ([`Prng::new(0x5eed)`](Prng::new)) row indices — the same relation
/// always yields the same hot set, so a skewed session's catalog (and
/// everything planned from it) is reproducible. Heaviest values win the
/// 64-entry cap; ties break by key order.
pub fn detect_hot_keys(rel: &Relation, comps: &[usize], threshold: f64) -> Vec<Key> {
    let n = rel.len();
    if n == 0 || comps.is_empty() {
        return Vec::new();
    }
    let pairs = rel.pairs();
    let mut counts: FxHashMap<Key, usize> = FxHashMap::default();
    let sampled = if n <= SKEW_SAMPLE_CAP {
        for (k, _) in pairs {
            *counts.entry(subkey(k, comps)).or_insert(0) += 1;
        }
        n
    } else {
        let mut idx = Prng::new(0x5eed).sample_indices(n, SKEW_SAMPLE_CAP);
        idx.sort_unstable();
        for i in idx {
            *counts.entry(subkey(&pairs[i].0, comps)).or_insert(0) += 1;
        }
        SKEW_SAMPLE_CAP
    };
    let mut hot: Vec<(usize, Key)> = counts
        .into_iter()
        .filter(|&(_, c)| c as f64 > threshold * sampled as f64)
        .map(|(k, c)| (c, k))
        .collect();
    hot.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    hot.truncate(SKEW_MAX_HOT);
    let mut keys: Vec<Key> = hot.into_iter().map(|(_, k)| k).collect();
    // Canonical order: the planner's membership set is unordered, but a
    // stable rendering keeps `Debug` output and cache signatures
    // independent of hash-map iteration.
    keys.sort_unstable();
    keys
}

/// Key-arity check for a declared schema vs an actual relation. Empty
/// relations carry no arity and pass (they bind at declared width).
fn check_arity(
    name: &str,
    declared: usize,
    actual: Option<usize>,
) -> Result<(), SessionError> {
    match actual {
        Some(got) if got != declared => Err(SessionError::ArityMismatch {
            table: name.to_string(),
            expected: declared,
            got,
        }),
        _ => Ok(()),
    }
}

/// Per-slot scan names of a query, slot-ordered. Every input slot must be
/// scanned under exactly one name.
fn scan_names(q: &Query) -> Result<Vec<String>, SessionError> {
    let mut names: Vec<Option<String>> = vec![None; q.n_slots];
    for node in &q.nodes {
        if let Op::Scan { slot, name } = &node.op {
            match &names[*slot] {
                None => names[*slot] = Some(name.clone()),
                Some(prev) if prev == name => {}
                Some(prev) => {
                    return Err(SessionError::Invalid(format!(
                        "input slot {slot} is scanned under two names ({prev}, {name})"
                    )));
                }
            }
        }
    }
    names
        .into_iter()
        .enumerate()
        .map(|(slot, n)| {
            n.ok_or_else(|| {
                SessionError::Invalid(format!("input slot {slot} has no TableScan node"))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ra::{Chunk, Key};

    fn rel2(n: i64) -> Relation {
        let mut r = Relation::new();
        for i in 0..n {
            r.insert(Key::k2(i, (i * 3) % n.max(1)), Chunk::filled(2, 2, 1.0));
        }
        r
    }

    #[test]
    fn register_lookup_drop_roundtrip() {
        let sess = Session::new(ClusterConfig::new(2));
        sess.register("A", &["row", "col"], &rel2(6)).unwrap();
        assert_eq!(sess.tables().len(), 1);
        let info = &sess.tables()[0];
        assert_eq!(info.name, "A");
        assert_eq!(info.arity, 2);
        assert_eq!(info.rows, 6);
        assert!(sess.table("A").is_some());
        assert!(sess.table("B").is_none());
        // Duplicate name is refused; dropping frees it.
        assert!(matches!(
            sess.register("A", &["row", "col"], &rel2(2)),
            Err(SessionError::DuplicateTable(_))
        ));
        sess.drop_table("A").unwrap();
        assert!(matches!(
            sess.drop_table("A"),
            Err(SessionError::UnknownTable(_))
        ));
        sess.register("A", &["row", "col"], &rel2(2)).unwrap();
        assert_eq!(sess.tables().len(), 1);
    }

    #[test]
    fn arity_and_worker_mismatches_are_typed() {
        let sess = Session::new(ClusterConfig::new(2));
        assert!(matches!(
            sess.register("A", &["row"], &rel2(4)),
            Err(SessionError::ArityMismatch {
                expected: 1,
                got: 2,
                ..
            })
        ));
        let wrong_w = PartitionedRelation::hash_full(&rel2(4), 3);
        assert!(matches!(
            sess.register_partitioned("A", &["row", "col"], wrong_w),
            Err(SessionError::Invalid(_))
        ));
        // HashOn component out of range.
        assert!(matches!(
            sess.register_with_layout("A", &["row", "col"], &rel2(4), &SlotLayout::HashOn(vec![5])),
            Err(SessionError::Invalid(_))
        ));
    }

    #[test]
    fn registration_charges_ingest_once() {
        let sess = Session::new(ClusterConfig::new(4));
        let r = rel2(8);
        sess.register("A", &["row", "col"], &r).unwrap();
        assert_eq!(sess.stats().bytes_ingested, r.nbytes() as u64);
        sess.register_with_layout("P", &["row", "col"], &r, &SlotLayout::Replicated)
            .unwrap();
        assert_eq!(
            sess.stats().bytes_ingested,
            r.nbytes() as u64 + r.nbytes() as u64 * 4
        );
        sess.reset_stats();
        assert_eq!(sess.stats(), ExecStats::default());
    }

    #[test]
    fn insert_routes_by_base_partitioning_and_preserves_untouched_shards() {
        let sess = Session::new(ClusterConfig::new(4));
        sess.register("A", &["row", "col"], &rel2(8)).unwrap();
        let before = sess.table("A").unwrap();
        // One new key: exactly one shard rebuilds, the rest keep handles.
        let k = Key::k2(100, 0);
        sess.insert("A", vec![(k, Chunk::filled(2, 2, 9.0))]).unwrap();
        let after = sess.table("A").unwrap();
        let owner = shuffle::owner(&k, &[0, 1], 4);
        let mut untouched = 0;
        for wi in 0..4 {
            if wi == owner {
                assert_eq!(after.shards[wi].len(), before.shards[wi].len() + 1);
                assert!(after.shards[wi].contains(&k));
            } else {
                assert!(Arc::ptr_eq(&before.shards[wi], &after.shards[wi]));
                untouched += 1;
            }
        }
        assert_eq!(untouched, 3);
        // The delta log holds one +1 batch placed like the base.
        let deltas = sess.table_deltas("A").unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].0, 1);
        assert_eq!(deltas[0].1.len(), 1);
        assert!(deltas[0].1.shards[owner].contains(&k));
        let info = &sess.tables()[0];
        assert_eq!(info.epoch, 1);
        assert_eq!(info.delta_rows, 1);
        assert_eq!(sess.stats().delta_rows_applied, 1);
    }

    #[test]
    fn delete_rebuilds_owning_shards_and_logs_removed_tuples() {
        let sess = Session::new(ClusterConfig::new(2));
        sess.register("A", &["row", "col"], &rel2(6)).unwrap();
        let before = sess.table("A").unwrap();
        let k = Key::k2(0, 0);
        sess.delete("A", &[k]).unwrap();
        let after = sess.table("A").unwrap();
        assert_eq!(after.len(), before.len() - 1);
        assert!(!after.shards.iter().any(|s| s.contains(&k)));
        // Shards that held no deleted key keep their handles.
        let owner = shuffle::owner(&k, &[0, 1], 2);
        for wi in 0..2 {
            if wi != owner {
                assert!(Arc::ptr_eq(&before.shards[wi], &after.shards[wi]));
            }
        }
        let deltas = sess.table_deltas("A").unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].0, -1);
        assert!(deltas[0].1.shards[owner].contains(&k));
        assert_eq!(sess.tables()[0].epoch, 1);
    }

    #[test]
    fn delta_batches_validate_before_applying() {
        let sess = Session::new(ClusterConfig::new(2));
        sess.register("A", &["row", "col"], &rel2(4)).unwrap();
        let c = || Chunk::filled(2, 2, 1.0);
        // Empty batches, duplicate keys in one batch, existing/missing
        // keys, and arity mismatches are all typed refusals — and none of
        // them advances the epoch.
        assert!(matches!(
            sess.insert("A", vec![]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.insert("A", vec![(Key::k2(9, 9), c()), (Key::k2(9, 9), c())]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.insert("A", vec![(Key::k2(0, 0), c())]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.insert("A", vec![(Key::k1(7), c())]),
            Err(SessionError::ArityMismatch { .. })
        ));
        assert!(matches!(
            sess.delete("A", &[]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.delete("A", &[Key::k2(50, 50)]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.delete("A", &[Key::k2(0, 0), Key::k2(0, 0)]),
            Err(SessionError::Invalid(_))
        ));
        assert!(matches!(
            sess.insert("missing", vec![(Key::k2(0, 0), c())]),
            Err(SessionError::UnknownTable(_))
        ));
        assert_eq!(sess.tables()[0].epoch, 0);
        assert_eq!(sess.stats().delta_rows_applied, 0);
    }

    #[test]
    fn reregistration_mints_a_new_generation() {
        let sess = Session::new(ClusterConfig::new(2));
        sess.register("A", &["row", "col"], &rel2(4)).unwrap();
        let (_, gen0, _, _) = sess.table_delta_state("A").unwrap();
        sess.drop_table("A").unwrap();
        sess.register("A", &["row", "col"], &rel2(2)).unwrap();
        let (_, gen1, epoch1, _) = sess.table_delta_state("A").unwrap();
        assert_ne!(gen0, gen1);
        assert_eq!(epoch1, 0);
        let e = SessionError::StaleEpoch {
            table: "A".into(),
            bound: gen0,
            current: gen1,
        };
        assert!(e.to_string().contains("stale"));
    }

    #[test]
    fn ingest_sampler_annotates_hot_tables_and_skips_uniform() {
        let sess = Session::new(ClusterConfig::new(2).with_skew_threshold(0.25));
        // 60% of rows share dst vertex 0 → hot under HashOn([0]).
        let mut e = Relation::new();
        for i in 0..12 {
            e.insert(Key::k2(0, i), Chunk::filled(1, 1, 1.0));
        }
        for i in 0..8 {
            e.insert(Key::k2(1 + i, 100 + i), Chunk::filled(1, 1, 1.0));
        }
        sess.register_with_layout("E", &["dst", "src"], &e, &SlotLayout::HashOn(vec![0]))
            .unwrap();
        let info = &sess.tables()[0];
        assert!(
            info.partitioning.contains("SkewHash"),
            "hot table must be annotated, got {}",
            info.partitioning
        );
        assert_eq!(sess.stats().hot_keys_detected, 1);
        // The annotation is metadata only: placement matches plain Hash,
        // and inserts still route by the base components.
        let k = Key::k2(0, 500);
        sess.insert("E", vec![(k, Chunk::filled(1, 1, 2.0))]).unwrap();
        let t = sess.table("E").unwrap();
        assert!(t.shards[shuffle::owner(&k, &[0], 2)].contains(&k));
        // Uniform keys: no annotation, counter untouched.
        sess.register("U", &["row", "col"], &rel2(8)).unwrap();
        let u = sess.tables().into_iter().find(|t| t.name == "U").unwrap();
        assert!(u.partitioning.starts_with("Hash"), "got {}", u.partitioning);
        assert_eq!(sess.stats().hot_keys_detected, 1);
        // The signature accessor sees the annotation (serve cache key).
        let sigs = sess.table_part_sigs(&["E".into(), "U".into(), "missing".into()]);
        assert!(sigs[0].as_deref().unwrap().contains("SkewHash"));
        assert!(sigs[1].as_deref().unwrap().starts_with("Hash"));
        assert!(sigs[2].is_none());
    }

    #[test]
    fn replicated_tables_take_deltas_on_every_shard() {
        let sess = Session::new(ClusterConfig::new(2));
        sess.register_with_layout("P", &["i"], &{
            let mut r = Relation::new();
            r.insert(Key::k1(0), Chunk::filled(1, 1, 1.0));
            r
        }, &SlotLayout::Replicated)
            .unwrap();
        sess.insert("P", vec![(Key::k1(1), Chunk::filled(1, 1, 2.0))])
            .unwrap();
        let p = sess.table("P").unwrap();
        for wi in 0..2 {
            assert_eq!(p.shards[wi].len(), 2);
            assert!(p.shards[wi].contains(&Key::k1(1)));
        }
    }
}
