//! Lazy execution handles: a [`Frame`] is a query bound to a session's
//! catalog, not yet run. `collect` executes it, `explain` reports the
//! physical plan the executor actually took, and `grad` differentiates
//! it — all through the session's persistent worker pool, all charging
//! the session's accumulated [`ExecStats`].

use super::{Session, SessionError};
use crate::autodiff::backward_graph;
use crate::dist::exec::StageTrace;
use crate::dist::{DistTape, ExecStats, PartitionedRelation};
use crate::plan::factorize::{factorize_query_gated, FactorizedQuery};
use crate::ra::expr::{NodeId, Query};
use crate::ra::{Chunk, Relation};
use crate::sql::to_sql;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// A lazy, catalog-bound computation. Created by [`Session::sql`] or
/// [`Session::query`]; nothing executes until [`collect`](Frame::collect),
/// [`explain`](Frame::explain) or [`grad`](Frame::grad) is called.
///
/// The frame snapshots its input shard handles at bind time (`Arc`
/// bumps), so a later `drop_table`/`register` on the session does not
/// invalidate it — re-bind through the session to pick up new data.
/// Executions are memoized: `collect`/`grad` share one forward run, and
/// `explain`/`trace` share one *traced* run — so any sequence of calls
/// on a frame executes the forward at most twice, and repeated calls
/// re-execute nothing.
///
/// When the session's [`ClusterConfig::factorize_agg`] knob is on
/// (default) and the bound plan has a Σ-over-⋈ the
/// [`factorize_query_gated`] pass can legally push below the join,
/// `collect`/`explain`/`trace` run the *factorized* plan (bitwise
/// identical output, less shuffle traffic) and memoize it separately
/// from the plain forward. [`grad`](Frame::grad) always runs the plain
/// forward — the backward query reads intermediate tape entries whose
/// values the rewrite changes — and instead factorizes the *backward*
/// plan, whose gradient Σs are rewrite candidates of their own.
///
/// [`ClusterConfig::factorize_agg`]: crate::dist::ClusterConfig::factorize_agg
pub struct Frame<'s> {
    sess: &'s Session,
    query: Query,
    /// Catalog table name per input slot.
    names: Vec<String>,
    inputs: Vec<PartitionedRelation>,
    arities: Vec<usize>,
    /// Memoized forward execution of the plan *as written* (tape handles
    /// + that run's stats) — inputs are immutable snapshots, so reuse is
    /// sound. `grad` feeds the backward query from this tape, so it must
    /// hold as-written intermediate values.
    fwd: RefCell<Option<(DistTape, ExecStats)>>,
    /// Lazily computed factorized rewrite of `query` (`Some(None)` once
    /// computed and refused — the legality/data gates said no, or the
    /// session knob is off).
    fact: RefCell<Option<Option<Rc<FactorizedQuery>>>>,
    /// Memoized *factorized* forward run, kept separate from `fwd`:
    /// only the final output is bitwise identical, so this tape must
    /// never be served where as-written intermediates are expected.
    fxd: RefCell<Option<(DistTape, ExecStats)>>,
    /// Memoized traced run (the per-stage records behind
    /// `explain`/`trace`).
    traced: RefCell<Option<(Vec<StageTrace>, ExecStats)>>,
}

impl<'s> Frame<'s> {
    pub(crate) fn new(
        sess: &'s Session,
        query: Query,
        names: Vec<String>,
        inputs: Vec<PartitionedRelation>,
        arities: Vec<usize>,
    ) -> Frame<'s> {
        Frame {
            sess,
            query,
            names,
            inputs,
            arities,
            fwd: RefCell::new(None),
            fact: RefCell::new(None),
            fxd: RefCell::new(None),
            traced: RefCell::new(None),
        }
    }

    /// The factorized rewrite of the bound plan, if the session knob is
    /// on and the legality + partition-aware data gates accept one.
    /// Computed once per frame (inputs are immutable snapshots).
    fn factorized(&self) -> Option<Rc<FactorizedQuery>> {
        if let Some(f) = self.fact.borrow().as_ref() {
            return f.clone();
        }
        let f = if self.sess.cfg().factorize_agg {
            factorize_query_gated(&self.query, &self.arities, &self.inputs).map(Rc::new)
        } else {
            None
        };
        *self.fact.borrow_mut() = Some(f.clone());
        f
    }

    /// The memoized factorized run — the analogue of [`Self::forward`]
    /// for the rewritten plan, executed with its Σ exchange hints.
    fn forward_factorized(
        &self,
        f: &FactorizedQuery,
    ) -> Result<(DistTape, ExecStats), SessionError> {
        if let Some((tape, stats)) = self.fxd.borrow().as_ref() {
            return Ok((tape.clone(), *stats));
        }
        let (tape, stats) =
            self.sess
                .run_tape_hinted(&f.query, &self.inputs, &f.agg_exchange, None)?;
        *self.fxd.borrow_mut() = Some((tape.clone(), stats));
        Ok((tape, stats))
    }

    /// The memoized forward run: executes on the session pool the first
    /// time (charging the session stats once), serves tape handle copies
    /// afterwards.
    fn forward(&self) -> Result<(DistTape, ExecStats), SessionError> {
        if let Some((tape, stats)) = self.fwd.borrow().as_ref() {
            return Ok((tape.clone(), *stats));
        }
        let (tape, stats) = self.sess.run_tape(&self.query, &self.inputs, None)?;
        *self.fwd.borrow_mut() = Some((tape.clone(), stats));
        Ok((tape, stats))
    }

    /// The bound functional-RA plan.
    pub fn query(&self) -> &Query {
        &self.query
    }

    /// The bound plan rendered back as SQL (the Fig. 4/5 demonstration).
    pub fn to_sql(&self) -> String {
        to_sql(&self.query)
    }

    /// Execute and gather the output relation onto the driver.
    pub fn collect(&self) -> Result<Relation, SessionError> {
        let (part, _) = self.collect_partitioned()?;
        Ok(part.gather_in(self.sess.comm_pool()))
    }

    /// Execute (or serve the memoized run), returning the
    /// still-partitioned output (a handle copy out of the tape) plus the
    /// run's [`ExecStats`] — the session accumulated them when the run
    /// happened.
    pub fn collect_partitioned(&self) -> Result<(PartitionedRelation, ExecStats), SessionError> {
        if let Some(f) = self.factorized() {
            let (tape, stats) = self.forward_factorized(&f)?;
            return Ok((tape.rels[f.node_map[self.query.output]].clone(), stats));
        }
        let (tape, stats) = self.forward()?;
        Ok((tape.rels[self.query.output].clone(), stats))
    }

    /// Execute with stage tracing and render the physical plan the
    /// executor took: one line per stage with the operator, the join
    /// strategy the cost-based planner picked, the output partitioning
    /// invariant, and the shuffle traffic (EXPLAIN ANALYZE semantics —
    /// the plan is what actually ran on this session's cluster shape).
    pub fn explain(&self) -> Result<String, SessionError> {
        let (trace, stats) = self.trace()?;
        let mut out = String::new();
        out.push_str(&format!(
            "plan over {} worker(s), backend {}:\n",
            self.sess.workers(),
            self.sess.backend_name()
        ));
        if let Some(f) = self.factorized() {
            // Stage node ids below are ids in the rewritten plan.
            for r in &f.rewrites {
                out.push_str(&format!("rewrite: {}\n", r.render()));
            }
        }
        out.push_str(&format!(
            "{:>5} {:<5} {:<30} {:<22} {:>12} {:>6} {:>6} {:>10}\n",
            "node", "op", "strategy", "partitioning", "bytes", "msgs", "spill", "elided"
        ));
        for t in &trace {
            let strat = match &t.strategy {
                Some(s) => format!("{s:?}"),
                None => "-".to_string(),
            };
            let node = format!("v{}", t.node);
            out.push_str(&format!(
                "{:>5} {:<5} {:<30} {:<22} {:>12} {:>6} {:>6} {:>10}\n",
                node,
                t.op,
                strat,
                t.out_part,
                t.bytes_shuffled,
                t.msgs,
                t.spill_passes,
                t.bytes_shuffle_elided
            ));
        }
        out.push_str(&format!(
            "totals: {} stage(s), {} B shuffled in {} msg(s), \
             {} B elided across {} elided shuffle(s), {} spill event(s) \
             ({} B spilled to disk, {} B re-read), \
             virtual {:.6}s (compute {:.6}s + net {:.6}s + spill {:.6}s)\n",
            stats.stages,
            stats.bytes_shuffled,
            stats.msgs,
            stats.bytes_shuffle_elided,
            stats.shuffles_elided,
            stats.spill_passes,
            stats.spill_bytes_written,
            stats.spill_bytes_read,
            stats.virtual_time_s,
            stats.compute_s,
            stats.net_s,
            stats.spill_s
        ));
        // Robustness line — all zeros on a healthy run with no fault
        // plan, and the first place to look when one isn't.
        out.push_str(&format!(
            "faults: {} injected, {} stage retr{}, {} shard(s) recomputed, \
             {} checkpoint B\n",
            stats.faults_injected,
            stats.stage_retries,
            if stats.stage_retries == 1 { "y" } else { "ies" },
            stats.shards_recomputed,
            stats.checkpoint_bytes
        ));
        Ok(out)
    }

    /// As [`explain`](Self::explain), returning the raw per-stage trace
    /// records instead of a rendered table. Memoized like
    /// [`collect`](Self::collect): the first traced call executes (and
    /// also warms the forward memo, so a following `collect`/`grad`
    /// reuses its tape); later calls serve the recorded trace.
    pub fn trace(&self) -> Result<(Vec<StageTrace>, ExecStats), SessionError> {
        if let Some((trace, stats)) = self.traced.borrow().as_ref() {
            return Ok((trace.clone(), *stats));
        }
        if let Some(f) = self.factorized() {
            // Trace the factorized plan — stage node ids are ids in
            // `f.query`. Warms the *factorized* memo only: the plain
            // `fwd` tape must keep as-written intermediates for `grad`.
            let mut trace = Vec::with_capacity(f.query.len());
            let (tape, stats) =
                self.sess
                    .run_tape_hinted(&f.query, &self.inputs, &f.agg_exchange, Some(&mut trace))?;
            *self.fxd.borrow_mut() = Some((tape, stats));
            *self.traced.borrow_mut() = Some((trace.clone(), stats));
            return Ok((trace, stats));
        }
        let mut trace = Vec::with_capacity(self.query.len());
        let (tape, stats) = self
            .sess
            .run_tape(&self.query, &self.inputs, Some(&mut trace))?;
        *self.fwd.borrow_mut() = Some((tape, stats));
        *self.traced.borrow_mut() = Some((trace.clone(), stats));
        Ok((trace, stats))
    }

    /// Differentiate the computation w.r.t. table `wrt` and execute the
    /// *generated backward query* (paper §5) on the same session pool:
    /// taped distributed forward, a ones seed shaped like the output
    /// (sharded exactly like the output), then the backward plan over the
    /// taped partitions. Returns the gathered gradient relation.
    pub fn grad(&self, wrt: &str) -> Result<Relation, SessionError> {
        let mut grads = self.grad_multi(&[wrt])?;
        Ok(grads.pop().expect("one wrt, one gradient").1)
    }

    /// [`grad`](Self::grad) for several tables at once — one shared
    /// forward tape, one backward DAG with an output per requested table.
    pub fn grad_multi(&self, wrt: &[&str]) -> Result<Vec<(String, Relation)>, SessionError> {
        let mut slots = Vec::with_capacity(wrt.len());
        for name in wrt {
            let slot = self
                .names
                .iter()
                .position(|n| n == name)
                .ok_or_else(|| SessionError::UnknownTable((*name).to_string()))?;
            slots.push(slot);
        }
        let plan = backward_graph(&self.query, &self.arities, &slots)
            .map_err(|e| SessionError::NotDifferentiable(format!("{e:#}")))?;

        // Forward with tape, on the session pool (memoized: a prior
        // `collect`/`explain` already paid for it).
        let (tape, _) = self.forward()?;

        // Seed ∂L/∂Out = ones shaped like each output tuple, sharded
        // exactly like the output so the invariant the backward planner
        // sees is the one the forward established.
        let out = &tape.rels[self.query.output];
        let seed_shards: Vec<Arc<Relation>> = out
            .shards
            .iter()
            .map(|s| {
                Arc::new(Relation::from_pairs(
                    s.iter()
                        .map(|(k, v)| (*k, Chunk::filled(v.rows(), v.cols(), 1.0)))
                        .collect(),
                ))
            })
            .collect();
        let seed = PartitionedRelation::from_shard_handles(seed_shards, out.part.clone());

        let mut bwd_inputs = Vec::with_capacity(1 + plan.tape_inputs.len());
        bwd_inputs.push(seed);
        for &fwd_node in &plan.tape_inputs {
            bwd_inputs.push(tape.rels[fwd_node].clone());
        }
        // Factorize the *backward* plan: its gradient Σs over tape joins
        // are pushdown candidates of their own, and the tape partitions
        // are live so the data gate can price the collapse. (The forward
        // above ran as-written — the rewrite changes intermediate tape
        // values, so only the backward, whose outputs are final, may be
        // rewritten.)
        let fact = self
            .sess
            .cfg()
            .factorize_agg
            .then(|| {
                let arities: Vec<usize> = bwd_inputs.iter().map(|p| p.key_arity()).collect();
                factorize_query_gated(&plan.query, &arities, &bwd_inputs)
            })
            .flatten();
        let (btape, outs): (DistTape, Vec<(usize, NodeId)>) = match &fact {
            Some(f) => {
                let (btape, _) =
                    self.sess
                        .run_tape_hinted(&f.query, &bwd_inputs, &f.agg_exchange, None)?;
                let outs = plan
                    .slot_outputs
                    .iter()
                    .map(|&(slot, node)| (slot, f.node_map[node]))
                    .collect();
                (btape, outs)
            }
            None => {
                let (btape, _) = self.sess.run_tape(&plan.query, &bwd_inputs, None)?;
                (btape, plan.slot_outputs.clone())
            }
        };
        Ok(outs
            .into_iter()
            .map(|(slot, node)| {
                (
                    self.names[slot].clone(),
                    btape.rels[node].gather_in(self.sess.comm_pool()),
                )
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ClusterConfig;
    use crate::kernels::NativeBackend;
    use crate::ra::eval::eval_query;
    use crate::ra::expr::matmul_query;
    use crate::ra::Key;
    use crate::util::Prng;

    fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
        let mut r = Relation::new();
        for i in 0..n {
            for j in 0..m {
                r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
            }
        }
        r
    }

    #[test]
    fn sql_and_query_frames_match_single_node() {
        let mut rng = Prng::new(41);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        for w in [1usize, 2, 4] {
            let mut sess = Session::new(ClusterConfig::new(w));
            sess.register("A", &["row", "col"], &a).unwrap();
            sess.register("B", &["row", "col"], &b).unwrap();
            // Via the RA query (scan names A/B resolve in the catalog)…
            let got = sess.query(&q).unwrap().collect().unwrap();
            assert!(got.approx_eq(&want, 1e-4), "w={w}");
            // …and via SQL.
            let got = sess
                .sql(
                    "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
                     FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
                )
                .unwrap()
                .collect()
                .unwrap();
            assert!(got.approx_eq(&want, 1e-4), "w={w} (sql)");
            assert!(sess.stats().stages > 0);
        }
    }

    #[test]
    fn explain_reports_stages_and_strategy() {
        let mut rng = Prng::new(42);
        let a = blocked(3, 2, 2, &mut rng);
        let b = blocked(2, 3, 2, &mut rng);
        let mut sess = Session::new(ClusterConfig::new(3));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let frame = sess.query(&matmul_query()).unwrap();
        let (trace, stats) = frame.trace().unwrap();
        assert_eq!(trace.len() as u64, stats.stages);
        let join = trace.iter().find(|t| t.op == "⋈").expect("a join stage");
        assert!(join.strategy.is_some(), "join stage records its plan");
        let text = frame.explain().unwrap();
        assert!(text.contains("⋈") && text.contains("totals:"), "{text}");
        // No fault plan configured: the robustness counters render as
        // zeros.
        assert!(
            text.contains("faults: 0 injected, 0 stage retries, 0 shard(s) recomputed"),
            "{text}"
        );
    }

    #[test]
    fn grad_matches_eager_autodiff() {
        let mut rng = Prng::new(43);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        // Eager single-node reference with a ones seed per output tuple.
        let tape = crate::ra::eval::eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap();
        let mut seed = Relation::new();
        for (k, v) in tape.rels[q.output].iter() {
            seed.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
        }
        let eager = crate::autodiff::grad_with_seed(&q, &tape, &seed, &NativeBackend).unwrap();
        for w in [1usize, 3] {
            let mut sess = Session::new(ClusterConfig::new(w));
            sess.register("A", &["row", "col"], &a).unwrap();
            sess.register("B", &["row", "col"], &b).unwrap();
            let frame = sess.query(&q).unwrap();
            let db = frame.grad("B").unwrap();
            assert!(db.approx_eq(eager.slot(1), 1e-4), "w={w}");
            let both = frame.grad_multi(&["A", "B"]).unwrap();
            assert_eq!(both[0].0, "A");
            assert!(both[0].1.approx_eq(eager.slot(0), 1e-4), "w={w}");
        }
    }

    #[test]
    fn grad_unknown_table_is_typed() {
        let mut rng = Prng::new(44);
        let a = blocked(2, 2, 2, &mut rng);
        let b = blocked(2, 2, 2, &mut rng);
        let mut sess = Session::new(ClusterConfig::new(1));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let frame = sess.query(&matmul_query()).unwrap();
        assert!(matches!(
            frame.grad("Z"),
            Err(SessionError::UnknownTable(_))
        ));
    }
}
