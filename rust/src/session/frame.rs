//! Lazy execution handles: a [`Frame`] is a query bound to a session's
//! catalog, not yet run. `collect` executes it, `explain` reports the
//! physical plan the executor actually took, and `grad` differentiates
//! it — all through the session's persistent worker pool, all charging
//! the session's accumulated [`ExecStats`].
//!
//! Frames are *incrementally maintained views*: a memoized frame whose
//! tables took [`Session::insert`]/[`Session::delete`] batches since its
//! last run does not recompute from scratch. On the next
//! `collect`/`grad`/`explain` it refreshes its slot bindings from the
//! catalog (replaying only the epochs it has not seen), asks the
//! [`delta_gate`] whether the plan's touched operators support delta
//! propagation, and — when they do — re-executes through the executor's
//! delta path: clean subtrees serve the previous tape's partitions
//! (`ExecStats::shards_reused`), insert-only changes replay as per-shard
//! suffixes through σ/⋈/Σ, and everything else recomputes from the
//! merged heads. Either way the result is bitwise identical to a full
//! recompute of the updated tables; a refused shape charges
//! `ExecStats::delta_fallbacks` and falls back whole. §7 of
//! ARCHITECTURE.md walks the rules.

use super::{Session, SessionError};
use crate::autodiff::backward_graph;
use crate::dist::delta::{DeltaCtx, NodeStatus, SlotDelta};
use crate::dist::exec::StageTrace;
use crate::dist::{DistTape, ExecStats, PartitionedRelation, Partitioning};
use crate::plan::delta_gate;
use crate::plan::factorize::{factorize_query_gated, FactorizedQuery};
use crate::ra::expr::{NodeId, Query};
use crate::ra::{Chunk, Relation};
use crate::sql::to_sql;
use crate::util::FxHashMap;
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

/// A memoized backward execution for one `wrt` slot set: the backward
/// tape (for lockstep delta on the next forward), the forward run it was
/// computed against, the factorization signature its plan ran under, and
/// the gathered gradients (served verbatim while the forward is
/// unchanged).
struct BwdMemo {
    fwd_run: u64,
    tape: DistTape,
    sig: Option<String>,
    grads: Vec<(String, Relation)>,
}

/// A lazy, catalog-bound computation. Created by [`Session::sql`] or
/// [`Session::query`]; nothing executes until [`collect`](Frame::collect),
/// [`explain`](Frame::explain) or [`grad`](Frame::grad) is called.
///
/// The frame binds each input slot to a catalog table's identity
/// generation and update epoch. Executions are memoized — `collect`/`grad`
/// share one forward run, `explain`/`trace` share one *traced* run — and
/// the memos survive catalog updates: when a bound table gains epochs
/// (via [`Session::insert`]/[`Session::delete`]) the next call replays
/// only the new deltas through the executor's incremental path (or falls
/// back to a bitwise-equal full recompute when the [`delta_gate`]
/// refuses; [`explain`](Frame::explain) renders which on its `delta:`
/// line). A table that was dropped leaves the frame running against its
/// frozen snapshot; a table that was dropped *and re-registered* makes
/// the frame refuse with [`SessionError::StaleEpoch`].
///
/// When the session's [`ClusterConfig::factorize_agg`] knob is on
/// (default) and the bound plan has a Σ-over-⋈ the
/// [`factorize_query_gated`] pass can legally push below the join,
/// `collect`/`explain`/`trace` run the *factorized* plan (bitwise
/// identical output, less shuffle traffic) and memoize it separately
/// from the plain forward. [`grad`](Frame::grad) always runs the plain
/// forward — the backward query reads intermediate tape entries whose
/// values the rewrite changes — and instead factorizes the *backward*
/// plan, whose gradient Σs are rewrite candidates of their own.
///
/// [`ClusterConfig::factorize_agg`]: crate::dist::ClusterConfig::factorize_agg
pub struct Frame<'s> {
    sess: &'s Session,
    query: Query,
    /// Catalog table name per input slot.
    names: Vec<String>,
    /// Current slot inputs — the catalog heads as of the last refresh
    /// (interior-mutable so a shared `&Frame` can replay new epochs).
    inputs: RefCell<Vec<PartitionedRelation>>,
    arities: Vec<usize>,
    /// Per-slot `(generation, epoch)` the inputs were bound at.
    binds: RefCell<Vec<(u64, u64)>>,
    /// Accumulated slot change since the `fwd` memo ran (refreshes
    /// compose onto it; an execution resets it to all-`Clean`).
    fwd_pending: RefCell<Vec<SlotDelta>>,
    /// Same, for the separately-memoized factorized run.
    fxd_pending: RefCell<Vec<SlotDelta>>,
    /// Delta rows accumulated behind each pending vector (for the
    /// `delta:` note and the replay charge).
    fwd_rows: Cell<u64>,
    fxd_rows: Cell<u64>,
    /// Memoized forward execution of the plan *as written* (tape handles
    /// + that run's stats + per-node change statuses vs the run before).
    /// `grad` feeds the backward query from this tape, so it must hold
    /// as-written intermediate values.
    fwd: RefCell<Option<(DistTape, ExecStats, Vec<NodeStatus>)>>,
    /// Monotone counter of plain forward executions (delta or fresh) —
    /// backward memos are tagged with it for lockstep maintenance.
    fwd_run: Cell<u64>,
    /// Lazily computed factorized rewrite of `query` (`Some(None)` once
    /// computed and refused — the legality/data gates said no, or the
    /// session knob is off). Invalidated by every slot refresh: the data
    /// gate prices live partitions.
    fact: RefCell<Option<Option<Rc<FactorizedQuery>>>>,
    /// Memoized *factorized* forward run, kept separate from `fwd`:
    /// only the final output is bitwise identical, so this tape must
    /// never be served where as-written intermediates are expected. The
    /// string is the rewrite signature the tape ran under — a delta
    /// replay is only sound against the same rewrite.
    fxd: RefCell<Option<(DistTape, ExecStats, String)>>,
    /// Memoized traced run (the per-stage records behind
    /// `explain`/`trace`); dropped on every slot refresh.
    traced: RefCell<Option<(Vec<StageTrace>, ExecStats)>>,
    /// Memoized backward runs, keyed by the requested `wrt` slots.
    bwd: RefCell<FxHashMap<Vec<usize>, BwdMemo>>,
    /// How the most recent forward-ish execution ran: `fresh`,
    /// `applied(N row(s))`, or `refused(reason)` — rendered by
    /// [`explain`](Frame::explain).
    delta_note: RefCell<String>,
}

/// Compose a newly observed slot change onto the change accumulated
/// since a memo ran. Two appends keep the *first* baseline (the memo saw
/// the table before both); anything involving a rewrite degrades to
/// `Dirty`.
fn compose(old: &SlotDelta, new: &SlotDelta) -> SlotDelta {
    match (old, new) {
        (SlotDelta::Clean, d) => d.clone(),
        (d, SlotDelta::Clean) => d.clone(),
        (SlotDelta::Appended { prev_rows }, SlotDelta::Appended { .. }) => SlotDelta::Appended {
            prev_rows: prev_rows.clone(),
        },
        _ => SlotDelta::Dirty,
    }
}

/// What a factorized tape is a function of, beyond the input data: which
/// rewrites applied and how nodes were remapped. A delta replay against
/// a memoized factorized tape is only sound if the current rewrite
/// decision matches the one the tape ran under.
fn fact_sig(f: &FactorizedQuery) -> String {
    let rws: Vec<String> = f.rewrites.iter().map(|r| r.render()).collect();
    format!(
        "{:?}|{:?}|{}|{:?}",
        f.node_map,
        f.agg_exchange,
        f.query.len(),
        rws
    )
}

/// A forward node's change status, viewed as the change of the backward
/// input slot it feeds.
fn status_to_slot(s: &NodeStatus) -> SlotDelta {
    match s {
        NodeStatus::Clean => SlotDelta::Clean,
        NodeStatus::Appended { prev_rows } => SlotDelta::Appended {
            prev_rows: prev_rows.clone(),
        },
        NodeStatus::Dirty => SlotDelta::Dirty,
    }
}

impl<'s> Frame<'s> {
    pub(crate) fn new(
        sess: &'s Session,
        query: Query,
        names: Vec<String>,
        inputs: Vec<PartitionedRelation>,
        arities: Vec<usize>,
        binds: Vec<(u64, u64)>,
    ) -> Frame<'s> {
        let n = inputs.len();
        Frame {
            sess,
            query,
            names,
            inputs: RefCell::new(inputs),
            arities,
            binds: RefCell::new(binds),
            fwd_pending: RefCell::new(vec![SlotDelta::Clean; n]),
            fxd_pending: RefCell::new(vec![SlotDelta::Clean; n]),
            fwd_rows: Cell::new(0),
            fxd_rows: Cell::new(0),
            fwd: RefCell::new(None),
            fwd_run: Cell::new(0),
            fact: RefCell::new(None),
            fxd: RefCell::new(None),
            traced: RefCell::new(None),
            bwd: RefCell::new(FxHashMap::default()),
            delta_note: RefCell::new("fresh".to_string()),
        }
    }

    /// The per-slot `(table, generation, epoch)` this frame's inputs are
    /// currently bound at. After a `collect`, this is exactly the catalog
    /// state the result was computed from (refresh re-binds before
    /// executing) — the serving layer's result cache keys entries on it.
    pub(crate) fn bindings(&self) -> Vec<(String, u64, u64)> {
        let binds = self.binds.borrow();
        self.names
            .iter()
            .zip(binds.iter())
            .map(|(n, &(gen, epoch))| (n.clone(), gen, epoch))
            .collect()
    }

    /// Re-bind every slot to the catalog's current epoch, staging the
    /// observed change for the memoized runs to replay. A dropped table
    /// freezes at its bound snapshot; a re-registered one (new identity
    /// generation) refuses with [`SessionError::StaleEpoch`].
    fn refresh(&self) -> Result<(), SessionError> {
        let mut inputs = self.inputs.borrow_mut();
        let mut binds = self.binds.borrow_mut();
        let mut fwd_pending = self.fwd_pending.borrow_mut();
        let mut fxd_pending = self.fxd_pending.borrow_mut();
        let mut changed_any = false;
        for i in 0..self.names.len() {
            let Some((head, gen, epoch, batches)) = self.sess.table_delta_state(&self.names[i])
            else {
                continue; // dropped: keep executing the frozen snapshot
            };
            let (bgen, bepoch) = binds[i];
            if gen != bgen {
                return Err(SessionError::StaleEpoch {
                    table: self.names[i].clone(),
                    bound: bgen,
                    current: gen,
                });
            }
            if epoch == bepoch {
                continue;
            }
            // Replay the epochs this frame has not seen: batch j produced
            // epoch j + 1, so the fresh ones are batches[bepoch..epoch].
            let fresh = &batches[bepoch as usize..epoch as usize];
            let rows: u64 = fresh.iter().map(|&(_, r)| r).sum();
            let all_inserts = fresh.iter().all(|&(s, _)| s == 1);
            let replicated = matches!(inputs[i].part, Partitioning::Replicated);
            // A skew-annotated table never replays as a suffix: the delta
            // shifted its key frequencies, so the hot-key annotation the
            // memoized tape's join plans were costed under is stale.
            // Forcing `Dirty` (and the explicit refusal in `execute`)
            // recomputes from the merged head — bitwise the same answer.
            let skewed = matches!(inputs[i].part, Partitioning::SkewHash { .. });
            let d = if all_inserts && !replicated && !skewed {
                SlotDelta::Appended {
                    prev_rows: inputs[i].shards.iter().map(|s| s.len()).collect(),
                }
            } else {
                SlotDelta::Dirty
            };
            fwd_pending[i] = compose(&fwd_pending[i], &d);
            fxd_pending[i] = compose(&fxd_pending[i], &d);
            inputs[i] = head;
            binds[i] = (gen, epoch);
            self.fwd_rows.set(self.fwd_rows.get() + rows);
            self.fxd_rows.set(self.fxd_rows.get() + rows);
            changed_any = true;
        }
        if changed_any {
            // The traced records and the rewrite decision are functions
            // of the data; recompute both against the new heads.
            *self.traced.borrow_mut() = None;
            *self.fact.borrow_mut() = None;
        }
        Ok(())
    }

    /// The factorized rewrite of the bound plan, if the session knob is
    /// on and the legality + partition-aware data gates accept one.
    /// Computed once per refresh (the data gate prices the current
    /// partitions).
    fn factorized(&self) -> Option<Rc<FactorizedQuery>> {
        if let Some(f) = self.fact.borrow().as_ref() {
            return f.clone();
        }
        let f = if self.sess.cfg().factorize_agg {
            let inputs = self.inputs.borrow();
            factorize_query_gated(&self.query, &self.arities, &inputs[..]).map(Rc::new)
        } else {
            None
        };
        *self.fact.borrow_mut() = Some(f.clone());
        f
    }

    /// One forward-ish execution: replay the staged delta against the
    /// previous tape when the [`delta_gate`] admits the plan's touched
    /// operators, otherwise recompute from the merged heads (charging a
    /// fallback only when there was a memo to maintain). Returns the new
    /// tape, stats, per-node statuses, and the `delta:` note.
    #[allow(clippy::too_many_arguments)]
    fn execute(
        &self,
        q: &Query,
        inputs: &[PartitionedRelation],
        agg_exchange: &[(NodeId, Vec<usize>)],
        trace: Option<&mut Vec<StageTrace>>,
        prev: Option<DistTape>,
        pending: &[SlotDelta],
        rows: u64,
    ) -> Result<(DistTape, ExecStats, Vec<NodeStatus>, String), SessionError> {
        if let Some(prev) = prev {
            if pending.iter().any(|d| !matches!(d, SlotDelta::Clean)) {
                // Deltas on a skew-partitioned table refuse outright: the
                // hot-key annotation was sampled from the pre-delta data,
                // so the only sound (and bitwise-equal) answer is a full
                // recompute from the merged head.
                let skew_changed = pending.iter().zip(inputs).any(|(d, p)| {
                    !matches!(d, SlotDelta::Clean)
                        && matches!(p.part, Partitioning::SkewHash { .. })
                });
                if skew_changed {
                    self.sess.charge_delta_fallback();
                    let (tape, stats, statuses) =
                        self.sess.run_tape_delta(q, inputs, agg_exchange, trace, None)?;
                    return Ok((
                        tape,
                        stats,
                        statuses,
                        "refused(delta on a skew-partitioned table — hot-key annotation \
                         is stale)"
                            .to_string(),
                    ));
                }
                let changed: Vec<bool> = pending
                    .iter()
                    .map(|d| !matches!(d, SlotDelta::Clean))
                    .collect();
                match delta_gate(q, &changed) {
                    Ok(()) => {
                        let ctx = DeltaCtx {
                            prev,
                            slots: pending.to_vec(),
                        };
                        let (tape, stats, statuses) =
                            self.sess
                                .run_tape_delta(q, inputs, agg_exchange, trace, Some(&ctx))?;
                        self.sess.charge_delta_rows(rows);
                        return Ok((tape, stats, statuses, format!("applied({rows} row(s))")));
                    }
                    Err(why) => {
                        self.sess.charge_delta_fallback();
                        let (tape, stats, statuses) =
                            self.sess.run_tape_delta(q, inputs, agg_exchange, trace, None)?;
                        return Ok((tape, stats, statuses, format!("refused({why})")));
                    }
                }
            }
        }
        let (tape, stats, statuses) =
            self.sess.run_tape_delta(q, inputs, agg_exchange, trace, None)?;
        Ok((tape, stats, statuses, "fresh".to_string()))
    }

    /// The memoized forward run of the plan as written: serves tape
    /// handle copies while the bound epochs are current, replays staged
    /// deltas when they are not.
    fn forward(&self) -> Result<(DistTape, ExecStats), SessionError> {
        let pending: Vec<SlotDelta> = self.fwd_pending.borrow().clone();
        if pending.iter().all(|d| matches!(d, SlotDelta::Clean)) {
            if let Some((tape, stats, _)) = self.fwd.borrow().as_ref() {
                return Ok((tape.clone(), *stats));
            }
        }
        let prev = self.fwd.borrow_mut().take().map(|(t, _, _)| t);
        let rows = self.fwd_rows.replace(0);
        let inputs = self.inputs.borrow().clone();
        let (tape, stats, statuses, note) =
            self.execute(&self.query, &inputs, &[], None, prev, &pending, rows)?;
        *self.fwd.borrow_mut() = Some((tape.clone(), stats, statuses));
        self.fwd_pending
            .borrow_mut()
            .iter_mut()
            .for_each(|d| *d = SlotDelta::Clean);
        self.fwd_run.set(self.fwd_run.get() + 1);
        *self.delta_note.borrow_mut() = note;
        Ok((tape, stats))
    }

    /// The memoized factorized run — the analogue of [`Self::forward`]
    /// for the rewritten plan, executed with its Σ exchange hints. A
    /// staged delta replays only if the current rewrite decision matches
    /// the memoized tape's signature; a changed rewrite runs fresh (that
    /// is plan drift, not a gate refusal — no fallback charged).
    fn forward_factorized(
        &self,
        f: &FactorizedQuery,
    ) -> Result<(DistTape, ExecStats), SessionError> {
        let sig = fact_sig(f);
        let pending: Vec<SlotDelta> = self.fxd_pending.borrow().clone();
        if pending.iter().all(|d| matches!(d, SlotDelta::Clean)) {
            if let Some((tape, stats, s)) = self.fxd.borrow().as_ref() {
                if *s == sig {
                    return Ok((tape.clone(), *stats));
                }
            }
        }
        let prev = self
            .fxd
            .borrow_mut()
            .take()
            .and_then(|(t, _, s)| (s == sig).then_some(t));
        let rows = self.fxd_rows.replace(0);
        let inputs = self.inputs.borrow().clone();
        let (tape, stats, _, note) =
            self.execute(&f.query, &inputs, &f.agg_exchange, None, prev, &pending, rows)?;
        *self.fxd.borrow_mut() = Some((tape.clone(), stats, sig));
        self.fxd_pending
            .borrow_mut()
            .iter_mut()
            .for_each(|d| *d = SlotDelta::Clean);
        *self.delta_note.borrow_mut() = note;
        Ok((tape, stats))
    }

    /// The bound functional-RA plan.
    pub fn query(&self) -> &Query {
        &self.query
    }

    /// The bound plan rendered back as SQL (the Fig. 4/5 demonstration).
    pub fn to_sql(&self) -> String {
        to_sql(&self.query)
    }

    /// Execute and gather the output relation onto the driver.
    pub fn collect(&self) -> Result<Relation, SessionError> {
        let (part, _) = self.collect_partitioned()?;
        Ok(part.gather_in(self.sess.comm_pool()))
    }

    /// Execute (or serve the memoized run, replaying any catalog deltas
    /// applied since), returning the still-partitioned output (a handle
    /// copy out of the tape) plus the run's [`ExecStats`] — the session
    /// accumulated them when the run happened.
    pub fn collect_partitioned(&self) -> Result<(PartitionedRelation, ExecStats), SessionError> {
        self.refresh()?;
        if let Some(f) = self.factorized() {
            let (tape, stats) = self.forward_factorized(&f)?;
            return Ok((tape.rels[f.node_map[self.query.output]].clone(), stats));
        }
        let (tape, stats) = self.forward()?;
        Ok((tape.rels[self.query.output].clone(), stats))
    }

    /// Execute with stage tracing and render the physical plan the
    /// executor took: one line per stage with the operator, the join
    /// strategy the cost-based planner picked, the output partitioning
    /// invariant, and the shuffle traffic (EXPLAIN ANALYZE semantics —
    /// the plan is what actually ran on this session's cluster shape).
    pub fn explain(&self) -> Result<String, SessionError> {
        let (trace, stats) = self.trace()?;
        let mut out = String::new();
        out.push_str(&format!(
            "plan over {} worker(s), backend {}:\n",
            self.sess.workers(),
            self.sess.backend_name()
        ));
        if let Some(f) = self.factorized() {
            // Stage node ids below are ids in the rewritten plan.
            for r in &f.rewrites {
                out.push_str(&format!("rewrite: {}\n", r.render()));
            }
        }
        out.push_str(&format!(
            "{:>5} {:<5} {:<30} {:<22} {:>12} {:>6} {:>6} {:>10}\n",
            "node", "op", "strategy", "partitioning", "bytes", "msgs", "spill", "elided"
        ));
        for t in &trace {
            let strat = match &t.strategy {
                Some(s) => format!("{s:?}"),
                None => "-".to_string(),
            };
            let node = format!("v{}", t.node);
            out.push_str(&format!(
                "{:>5} {:<5} {:<30} {:<22} {:>12} {:>6} {:>6} {:>10}\n",
                node,
                t.op,
                strat,
                t.out_part,
                t.bytes_shuffled,
                t.msgs,
                t.spill_passes,
                t.bytes_shuffle_elided
            ));
        }
        out.push_str(&format!(
            "totals: {} stage(s), {} B shuffled in {} msg(s), \
             {} B elided across {} elided shuffle(s), {} spill event(s) \
             ({} B spilled to disk, {} B re-read), \
             virtual {:.6}s (compute {:.6}s + net {:.6}s + spill {:.6}s)\n",
            stats.stages,
            stats.bytes_shuffled,
            stats.msgs,
            stats.bytes_shuffle_elided,
            stats.shuffles_elided,
            stats.spill_passes,
            stats.spill_bytes_written,
            stats.spill_bytes_read,
            stats.virtual_time_s,
            stats.compute_s,
            stats.net_s,
            stats.spill_s
        ));
        // Robustness line — all zeros on a healthy run with no fault
        // plan, and the first place to look when one isn't.
        out.push_str(&format!(
            "faults: {} injected, {} stage retr{}, {} shard(s) recomputed, \
             {} checkpoint B\n",
            stats.faults_injected,
            stats.stage_retries,
            if stats.stage_retries == 1 { "y" } else { "ies" },
            stats.shards_recomputed,
            stats.checkpoint_bytes
        ));
        // Skew line — the heavy-hitter surface of this frame: how many
        // hot keys its bound tables carry (from the ingest sampler), and
        // what the traced run's skew strategies actually did about them.
        let hot_bound: usize = self
            .inputs
            .borrow()
            .iter()
            .filter_map(|p| p.part.hot_keys().map(|h| h.len()))
            .sum();
        out.push_str(&format!(
            "skew: {} hot key(s) bound, {} row(s) salted, {} B hot-replicated\n",
            hot_bound, stats.rows_salted, stats.bytes_hot_replicated
        ));
        // Incremental line — how the most recent forward execution ran:
        // `fresh` (no memo to maintain), `applied(N row(s))` (delta
        // replayed against the previous tape), or `refused(reason)` (the
        // delta gate fell back to a bitwise-equal full recompute).
        out.push_str(&format!("delta: {}\n", self.delta_note.borrow()));
        Ok(out)
    }

    /// As [`explain`](Self::explain), returning the raw per-stage trace
    /// records instead of a rendered table. Memoized like
    /// [`collect`](Self::collect): the first traced call executes (and
    /// also warms the forward memo, so a following `collect`/`grad`
    /// reuses its tape); later calls serve the recorded trace. Catalog
    /// deltas since the traced run drop the memo and re-trace (through
    /// the delta path where admitted — a reused stage traces with zero
    /// shuffle traffic).
    pub fn trace(&self) -> Result<(Vec<StageTrace>, ExecStats), SessionError> {
        self.refresh()?;
        if let Some((trace, stats)) = self.traced.borrow().as_ref() {
            return Ok((trace.clone(), *stats));
        }
        if let Some(f) = self.factorized() {
            // Trace the factorized plan — stage node ids are ids in
            // `f.query`. Warms the *factorized* memo only: the plain
            // `fwd` tape must keep as-written intermediates for `grad`.
            let sig = fact_sig(&f);
            let pending: Vec<SlotDelta> = self.fxd_pending.borrow().clone();
            let prev = self
                .fxd
                .borrow_mut()
                .take()
                .and_then(|(t, _, s)| (s == sig).then_some(t));
            let rows = self.fxd_rows.replace(0);
            let inputs = self.inputs.borrow().clone();
            let mut trace = Vec::with_capacity(f.query.len());
            let (tape, stats, _, note) = self.execute(
                &f.query,
                &inputs,
                &f.agg_exchange,
                Some(&mut trace),
                prev,
                &pending,
                rows,
            )?;
            *self.fxd.borrow_mut() = Some((tape, stats, sig));
            self.fxd_pending
                .borrow_mut()
                .iter_mut()
                .for_each(|d| *d = SlotDelta::Clean);
            *self.delta_note.borrow_mut() = note;
            *self.traced.borrow_mut() = Some((trace.clone(), stats));
            return Ok((trace, stats));
        }
        let pending: Vec<SlotDelta> = self.fwd_pending.borrow().clone();
        let prev = self.fwd.borrow_mut().take().map(|(t, _, _)| t);
        let rows = self.fwd_rows.replace(0);
        let inputs = self.inputs.borrow().clone();
        let mut trace = Vec::with_capacity(self.query.len());
        let (tape, stats, statuses, note) = self.execute(
            &self.query,
            &inputs,
            &[],
            Some(&mut trace),
            prev,
            &pending,
            rows,
        )?;
        *self.fwd.borrow_mut() = Some((tape, stats, statuses));
        self.fwd_pending
            .borrow_mut()
            .iter_mut()
            .for_each(|d| *d = SlotDelta::Clean);
        self.fwd_run.set(self.fwd_run.get() + 1);
        *self.delta_note.borrow_mut() = note;
        *self.traced.borrow_mut() = Some((trace.clone(), stats));
        Ok((trace, stats))
    }

    /// Differentiate the computation w.r.t. table `wrt` and execute the
    /// *generated backward query* (paper §5) on the same session pool:
    /// taped distributed forward, a ones seed shaped like the output
    /// (sharded exactly like the output), then the backward plan over the
    /// taped partitions. Returns the gathered gradient relation.
    pub fn grad(&self, wrt: &str) -> Result<Relation, SessionError> {
        let mut grads = self.grad_multi(&[wrt])?;
        Ok(grads.pop().expect("one wrt, one gradient").1)
    }

    /// [`grad`](Self::grad) for several tables at once — one shared
    /// forward tape, one backward DAG with an output per requested table.
    ///
    /// The backward is *maintained* alongside the forward: while the
    /// forward memo is current the gathered gradients serve from memo
    /// without executing anything, and when the forward advanced by one
    /// delta replay the backward replays in lockstep — the forward's
    /// per-node change statuses become the backward inputs' slot deltas
    /// (the seed mirrors the output's status), gated exactly like the
    /// forward. Any other drift (two forwards since the last grad, a
    /// changed backward factorization, a gate refusal) recomputes the
    /// backward fresh — bitwise the same either way.
    pub fn grad_multi(&self, wrt: &[&str]) -> Result<Vec<(String, Relation)>, SessionError> {
        self.refresh()?;
        let mut slots = Vec::with_capacity(wrt.len());
        for name in wrt {
            let slot = self
                .names
                .iter()
                .position(|n| n == name)
                .ok_or_else(|| SessionError::UnknownTable((*name).to_string()))?;
            slots.push(slot);
        }

        // Forward with tape, on the session pool (memoized: a prior
        // `collect`/`explain` already paid for it; a staled memo replays
        // its deltas here).
        let (tape, _) = self.forward()?;
        let run = self.fwd_run.get();
        if let Some(m) = self.bwd.borrow().get(&slots) {
            if m.fwd_run == run {
                return Ok(m.grads.clone());
            }
        }

        let plan = backward_graph(&self.query, &self.arities, &slots)
            .map_err(|e| SessionError::NotDifferentiable(format!("{e:#}")))?;

        // Seed ∂L/∂Out = ones shaped like each output tuple, sharded
        // exactly like the output so the invariant the backward planner
        // sees is the one the forward established.
        let out = &tape.rels[self.query.output];
        let seed_shards: Vec<Arc<Relation>> = out
            .shards
            .iter()
            .map(|s| {
                Arc::new(Relation::from_pairs(
                    s.iter()
                        .map(|(k, v)| (*k, Chunk::filled(v.rows(), v.cols(), 1.0)))
                        .collect(),
                ))
            })
            .collect();
        let seed = PartitionedRelation::from_shard_handles(seed_shards, out.part.clone());

        let mut bwd_inputs = Vec::with_capacity(1 + plan.tape_inputs.len());
        bwd_inputs.push(seed);
        for &fwd_node in &plan.tape_inputs {
            bwd_inputs.push(tape.rels[fwd_node].clone());
        }
        // Factorize the *backward* plan: its gradient Σs over tape joins
        // are pushdown candidates of their own, and the tape partitions
        // are live so the data gate can price the collapse. (The forward
        // above ran as-written — the rewrite changes intermediate tape
        // values, so only the backward, whose outputs are final, may be
        // rewritten.)
        let fact = self
            .sess
            .cfg()
            .factorize_agg
            .then(|| {
                let arities: Vec<usize> = bwd_inputs.iter().map(|p| p.key_arity()).collect();
                factorize_query_gated(&plan.query, &arities, &bwd_inputs)
            })
            .flatten();
        let sig = fact.as_ref().map(|f| fact_sig(f));

        // Lockstep maintenance: a backward memo exactly one forward run
        // behind, under the same factorization, replays the forward's
        // per-node change statuses as its slot deltas. The seed slot
        // mirrors the output node (same keys, ones payloads); tape-input
        // slots mirror the forward nodes they alias. Refusals here just
        // run fresh — the forward already accounted for this update's
        // delta path, so no extra fallback is charged.
        let bwd_query = fact.as_ref().map(|f| &f.query).unwrap_or(&plan.query);
        let prev_memo = self.bwd.borrow_mut().remove(&slots);
        let mut serve_prev = None;
        let delta_ctx = prev_memo.and_then(|m| {
            if m.fwd_run + 1 != run || m.sig != sig {
                return None;
            }
            let fwd = self.fwd.borrow();
            let statuses = &fwd.as_ref()?.2;
            let mut sds = Vec::with_capacity(bwd_inputs.len());
            sds.push(status_to_slot(&statuses[self.query.output]));
            for &n in &plan.tape_inputs {
                sds.push(status_to_slot(&statuses[n]));
            }
            drop(fwd);
            if sds.iter().all(|d| matches!(d, SlotDelta::Clean)) {
                // The forward re-ran but nothing the backward reads
                // changed: the memoized gradients are still exact.
                serve_prev = Some(m);
                return None;
            }
            if sds.iter().all(|d| matches!(d, SlotDelta::Dirty)) {
                return None; // nothing to reuse — fresh is cheaper
            }
            let changed: Vec<bool> = sds
                .iter()
                .map(|d| !matches!(d, SlotDelta::Clean))
                .collect();
            delta_gate(bwd_query, &changed).ok().map(|_| DeltaCtx {
                prev: m.tape,
                slots: sds,
            })
        });
        if let Some(mut m) = serve_prev {
            let grads = m.grads.clone();
            m.fwd_run = run;
            self.bwd.borrow_mut().insert(slots, m);
            return Ok(grads);
        }

        let agg_exchange: &[(NodeId, Vec<usize>)] =
            fact.as_ref().map(|f| f.agg_exchange.as_slice()).unwrap_or(&[]);
        let (btape, _, _) = self.sess.run_tape_delta(
            bwd_query,
            &bwd_inputs,
            agg_exchange,
            None,
            delta_ctx.as_ref(),
        )?;
        let outs: Vec<(usize, NodeId)> = match &fact {
            Some(f) => plan
                .slot_outputs
                .iter()
                .map(|&(slot, node)| (slot, f.node_map[node]))
                .collect(),
            None => plan.slot_outputs.clone(),
        };
        let grads: Vec<(String, Relation)> = outs
            .into_iter()
            .map(|(slot, node)| {
                (
                    self.names[slot].clone(),
                    btape.rels[node].gather_in(self.sess.comm_pool()),
                )
            })
            .collect();
        self.bwd.borrow_mut().insert(
            slots,
            BwdMemo {
                fwd_run: run,
                tape: btape,
                sig,
                grads: grads.clone(),
            },
        );
        Ok(grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::ClusterConfig;
    use crate::kernels::NativeBackend;
    use crate::ra::eval::eval_query;
    use crate::ra::expr::matmul_query;
    use crate::ra::Key;
    use crate::util::Prng;

    fn blocked(n: i64, m: i64, c: usize, rng: &mut Prng) -> Relation {
        let mut r = Relation::new();
        for i in 0..n {
            for j in 0..m {
                r.insert(Key::k2(i, j), Chunk::random(c, c, rng, 1.0));
            }
        }
        r
    }

    #[test]
    fn sql_and_query_frames_match_single_node() {
        let mut rng = Prng::new(41);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        let want = eval_query(&q, &[&a, &b], &NativeBackend).unwrap();
        for w in [1usize, 2, 4] {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register("A", &["row", "col"], &a).unwrap();
            sess.register("B", &["row", "col"], &b).unwrap();
            // Via the RA query (scan names A/B resolve in the catalog)…
            let got = sess.query(&q).unwrap().collect().unwrap();
            assert!(got.approx_eq(&want, 1e-4), "w={w}");
            // …and via SQL.
            let got = sess
                .sql(
                    "SELECT A.row, B.col, SUM(matmul(A.val, B.val)) \
                     FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
                )
                .unwrap()
                .collect()
                .unwrap();
            assert!(got.approx_eq(&want, 1e-4), "w={w} (sql)");
            assert!(sess.stats().stages > 0);
        }
    }

    #[test]
    fn explain_reports_stages_and_strategy() {
        let mut rng = Prng::new(42);
        let a = blocked(3, 2, 2, &mut rng);
        let b = blocked(2, 3, 2, &mut rng);
        let sess = Session::new(ClusterConfig::new(3));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let frame = sess.query(&matmul_query()).unwrap();
        let (trace, stats) = frame.trace().unwrap();
        assert_eq!(trace.len() as u64, stats.stages);
        let join = trace.iter().find(|t| t.op == "⋈").expect("a join stage");
        assert!(join.strategy.is_some(), "join stage records its plan");
        let text = frame.explain().unwrap();
        assert!(text.contains("⋈") && text.contains("totals:"), "{text}");
        // No fault plan configured: the robustness counters render as
        // zeros.
        assert!(
            text.contains("faults: 0 injected, 0 stage retries, 0 shard(s) recomputed"),
            "{text}"
        );
        // Never updated, never memoized-then-replayed: the incremental
        // line reports a fresh run.
        assert!(text.contains("delta: fresh"), "{text}");
    }

    #[test]
    fn grad_matches_eager_autodiff() {
        let mut rng = Prng::new(43);
        let a = blocked(3, 2, 4, &mut rng);
        let b = blocked(2, 3, 4, &mut rng);
        let q = matmul_query();
        // Eager single-node reference with a ones seed per output tuple.
        let tape = crate::ra::eval::eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap();
        let mut seed = Relation::new();
        for (k, v) in tape.rels[q.output].iter() {
            seed.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
        }
        let eager = crate::autodiff::grad_with_seed(&q, &tape, &seed, &NativeBackend).unwrap();
        for w in [1usize, 3] {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register("A", &["row", "col"], &a).unwrap();
            sess.register("B", &["row", "col"], &b).unwrap();
            let frame = sess.query(&q).unwrap();
            let db = frame.grad("B").unwrap();
            assert!(db.approx_eq(eager.slot(1), 1e-4), "w={w}");
            let both = frame.grad_multi(&["A", "B"]).unwrap();
            assert_eq!(both[0].0, "A");
            assert!(both[0].1.approx_eq(eager.slot(0), 1e-4), "w={w}");
        }
    }

    #[test]
    fn grad_unknown_table_is_typed() {
        let mut rng = Prng::new(44);
        let a = blocked(2, 2, 2, &mut rng);
        let b = blocked(2, 2, 2, &mut rng);
        let sess = Session::new(ClusterConfig::new(1));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let frame = sess.query(&matmul_query()).unwrap();
        assert!(matches!(
            frame.grad("Z"),
            Err(SessionError::UnknownTable(_))
        ));
    }

    #[test]
    fn insert_then_collect_replays_the_delta_bitwise() {
        let mut rng = Prng::new(45);
        let a = blocked(4, 3, 2, &mut rng);
        let b = blocked(3, 4, 2, &mut rng);
        let q = matmul_query();
        for w in [1usize, 2] {
            let sess = Session::new(ClusterConfig::new(w));
            sess.register("A", &["row", "col"], &a).unwrap();
            sess.register("B", &["row", "col"], &b).unwrap();
            let frame = sess.query(&q).unwrap();
            frame.collect().unwrap();
            // Grow A by one block row and re-collect the same frame.
            let batch: Vec<(Key, Chunk)> = (0..3)
                .map(|j| (Key::k2(9, j), Chunk::random(2, 2, &mut rng, 1.0)))
                .collect();
            sess.insert("A", batch.clone()).unwrap();
            let got = frame.collect().unwrap();
            // Oracle: a fresh session over the merged tables.
            let fresh = Session::new(ClusterConfig::new(w));
            let mut a2 = a.clone();
            for (k, v) in &batch {
                a2.insert(*k, v.clone());
            }
            fresh.register("A", &["row", "col"], &a2).unwrap();
            fresh.register("B", &["row", "col"], &b).unwrap();
            let want = fresh.query(&q).unwrap().collect().unwrap();
            assert_eq!(got.len(), want.len(), "w={w}");
            for (k, v) in want.iter() {
                let g = got.get(k).expect("key present");
                assert_eq!(g.data(), v.data(), "w={w} key {k}");
            }
        }
    }

    #[test]
    fn stale_frame_after_reregistration_is_typed() {
        let mut rng = Prng::new(46);
        let a = blocked(2, 2, 2, &mut rng);
        let b = blocked(2, 2, 2, &mut rng);
        let sess = Session::new(ClusterConfig::new(2));
        sess.register("A", &["row", "col"], &a).unwrap();
        sess.register("B", &["row", "col"], &b).unwrap();
        let frame = sess.query(&matmul_query()).unwrap();
        frame.collect().unwrap();
        // Dropping alone freezes the snapshot — the frame still serves.
        sess.drop_table("A").unwrap();
        frame.collect().unwrap();
        // Re-registering the name mints a new generation: stale.
        sess.register("A", &["row", "col"], &a).unwrap();
        assert!(matches!(
            frame.collect(),
            Err(SessionError::StaleEpoch { .. })
        ));
    }
}
