//! Algorithm 2 (`RAAutoDiff`), eager mode.

use super::rjp;
use crate::kernels::KernelBackend;
use crate::ra::eval::{eval_query_tape, Tape};
use crate::ra::expr::{Op, Query};
use crate::ra::{Chunk, Relation};
use anyhow::{bail, Result};

/// Per-input-slot gradients `∇Q_i(In_i)`.
#[derive(Debug)]
pub struct Gradients {
    pub by_slot: Vec<Option<Relation>>,
}

impl Gradients {
    pub fn slot(&self, i: usize) -> &Relation {
        self.by_slot[i].as_ref().expect("no gradient for slot")
    }
}

/// Reverse-mode autodiff with the canonical seed `{(keyOut, 1)}`: every
/// output tuple's gradient is a ones-chunk (for a scalar-loss query this
/// is the single tuple `(⟨⟩, 1.0)` of Algorithm 2 line 7).
pub fn grad(
    q: &Query,
    inputs: &[&Relation],
    backend: &dyn KernelBackend,
) -> Result<(Tape, Gradients)> {
    let slots: Vec<usize> = (0..q.n_slots).collect();
    grad_wrt(q, inputs, &slots, backend)
}

/// Like `grad`, but differentiating only with respect to `slots`: nodes
/// off every requested path are skipped (labels / data relations whose
/// kernels may have no vjp on that side get no gradient work at all).
pub fn grad_wrt(
    q: &Query,
    inputs: &[&Relation],
    slots: &[usize],
    backend: &dyn KernelBackend,
) -> Result<(Tape, Gradients)> {
    let tape = eval_query_tape(q, inputs, backend)?;
    let out = &tape.rels[q.output];
    let mut seed = Relation::with_capacity(out.len());
    for (k, v) in out.iter() {
        seed.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
    }
    let grads = grad_with_seed_wrt(q, &tape, &seed, slots, backend)?;
    Ok((tape, grads))
}

/// Reverse sweep over a taped forward execution with an explicit seed
/// gradient for the output relation.
pub fn grad_with_seed(
    q: &Query,
    tape: &Tape,
    seed: &Relation,
    backend: &dyn KernelBackend,
) -> Result<Gradients> {
    let slots: Vec<usize> = (0..q.n_slots).collect();
    grad_with_seed_wrt(q, tape, seed, &slots, backend)
}

/// Reverse sweep restricted to the nodes on a path to a requested slot.
pub fn grad_with_seed_wrt(
    q: &Query,
    tape: &Tape,
    seed: &Relation,
    slots: &[usize],
    backend: &dyn KernelBackend,
) -> Result<Gradients> {
    let needed = q.needed_for_slots(slots);
    // ∂Q/∂R_i per node, accumulated via relational add as consumers are
    // processed (Algorithm 2 lines 8–19).
    let mut node_grad: Vec<Option<Relation>> = vec![None; q.nodes.len()];
    node_grad[q.output] = Some(seed.clone());

    for i in (0..q.nodes.len()).rev() {
        let Some(g) = node_grad[i].take() else {
            continue; // no gradient flows through this node
        };
        let node = &q.nodes[i];
        match &node.op {
            Op::Scan { .. } | Op::Const { .. } => {
                // Leaves: keep the gradient for extraction below.
                node_grad[i] = Some(g);
                continue;
            }
            Op::Select { pred, proj, kernel } => {
                let child = node.children[0];
                if !needed[child] {
                    continue;
                }
                let gi = rjp::rjp_select(pred, proj, kernel, &g, &tape.rels[child], backend)?;
                accumulate(&mut node_grad[child], gi);
            }
            Op::Agg { grp, agg } => {
                let child = node.children[0];
                if !needed[child] {
                    continue;
                }
                let gi = rjp::rjp_agg(grp, agg, &g, &tape.rels[child], &tape.rels[i], backend)?;
                accumulate(&mut node_grad[child], gi);
            }
            Op::Join { pred, proj, kernel } => {
                let (cl, cr) = (node.children[0], node.children[1]);
                // Gradients flow only into needed, non-constant inputs.
                let want_l = needed[cl];
                let want_r = needed[cr];
                if !want_l && !want_r {
                    continue;
                }
                let jg = rjp::rjp_join(
                    pred,
                    proj,
                    kernel,
                    &g,
                    &tape.rels[cl],
                    &tape.rels[cr],
                    want_l,
                    want_r,
                    backend,
                )?;
                if let Some(gl) = jg.left {
                    accumulate(&mut node_grad[cl], gl);
                }
                if let Some(gr) = jg.right {
                    accumulate(&mut node_grad[cr], gr);
                }
            }
            Op::AddQ => {
                let (cl, cr) = (node.children[0], node.children[1]);
                if needed[cl] {
                    let gl = rjp::rjp_add(&g, &tape.rels[cl]);
                    accumulate(&mut node_grad[cl], gl);
                }
                if needed[cr] {
                    let gr = rjp::rjp_add(&g, &tape.rels[cr]);
                    accumulate(&mut node_grad[cr], gr);
                }
            }
        }
    }

    // Algorithm 2 line 20: for the i-th input, return ∂Q/∂R_j of the scan
    // node that consumed it.
    let mut by_slot: Vec<Option<Relation>> = vec![None; q.n_slots];
    for (id, node) in q.nodes.iter().enumerate() {
        if let Op::Scan { slot, .. } = &node.op {
            match node_grad[id].take() {
                Some(g) => match &mut by_slot[*slot] {
                    acc @ None => *acc = Some(g),
                    Some(acc) => {
                        // Same relation scanned in several places: total
                        // derivative sums the contributions.
                        for (k, v) in g.iter() {
                            acc.merge_add(*k, v.clone());
                        }
                    }
                },
                None => {
                    // A slot the loss does not depend on: zero gradient,
                    // represented by the empty relation.
                    if by_slot[*slot].is_none() {
                        by_slot[*slot] = Some(Relation::new());
                    }
                }
            }
        }
    }
    if by_slot.iter().any(|g| g.is_none()) {
        bail!("some input slot has no scan node");
    }
    Ok(Gradients { by_slot })
}

fn accumulate(slot: &mut Option<Relation>, g: Relation) {
    match slot {
        None => *slot = Some(g),
        Some(acc) => {
            for (k, v) in g.iter() {
                acc.merge_add(*k, v.clone());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{AggKernel, BinaryKernel, NativeBackend, UnaryKernel};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
    use crate::ra::Key;
    use std::sync::Arc;

    /// loss = Σ_k (x_k * w_k)  — gradient w.r.t. w is x.
    fn dot_loss_query(x: Relation) -> Query {
        let mut qb = QueryBuilder::new();
        let w = qb.scan(0, "w");
        let j = qb.join_const(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::Mul,
            w,
            Arc::new(x),
            "x",
        );
        let s = qb.agg(KeyProj::to_empty(), AggKernel::Sum, j);
        qb.finish(s)
    }

    #[test]
    fn grad_of_dot_product_is_other_vector() {
        let x = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(3.0)),
            (Key::k1(1), Chunk::scalar(-2.0)),
        ]);
        let w = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(4.0)),
        ]);
        let q = dot_loss_query(x);
        let (tape, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        // loss = 3 - 8 = -5
        assert_eq!(
            tape.output(&q).get(&Key::empty()).unwrap().as_scalar(),
            -5.0
        );
        let gw = grads.slot(0);
        assert_eq!(gw.get(&Key::k1(0)).unwrap().as_scalar(), 3.0);
        assert_eq!(gw.get(&Key::k1(1)).unwrap().as_scalar(), -2.0);
    }

    #[test]
    fn grad_through_select_chain() {
        // loss = Σ logistic(w)²  ⇒ dw = 2·σ(w)·σ'(w)
        let w = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(0.3))]);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "w");
        let l = qb.map(UnaryKernel::Logistic, 1, s);
        let sq = qb.map(UnaryKernel::Square, 1, l);
        let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, sq);
        let q = qb.finish(out);
        let (_, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        let sig = 1.0 / (1.0 + (-0.3f32).exp());
        let want = 2.0 * sig * sig * (1.0 - sig);
        let got = grads.slot(0).get(&Key::k1(0)).unwrap().as_scalar();
        assert!((got - want).abs() < 1e-5, "got {got} want {want}");
    }

    #[test]
    fn fanout_accumulates_total_derivative() {
        // loss = Σ (w + w∘w) — w consumed by two paths (scan has 2 parents)
        let w = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(3.0))]);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "w");
        let sq = qb.map(UnaryKernel::Square, 1, s);
        let both = qb.add(s, sq);
        let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, both);
        let q = qb.finish(out);
        let (_, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        // d/dw (w + w²) = 1 + 2w = 7
        assert_eq!(grads.slot(0).get(&Key::k1(0)).unwrap().as_scalar(), 7.0);
    }

    #[test]
    fn const_gets_no_gradient_and_unused_slot_zero() {
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(1.0))]);
        let q = dot_loss_query(x);
        let w = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(2.0))]);
        let (_, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        assert_eq!(grads.by_slot.len(), 1); // only the scan slot
        assert_eq!(grads.slot(0).len(), 1);
    }

    #[test]
    fn max_agg_subgradient() {
        // loss = max(w0, w1); routes gradient to the argmax
        let w = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(5.0)),
        ]);
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "w");
        let m = qb.agg(KeyProj::to_empty(), AggKernel::Max, s);
        let q = qb.finish(m);
        let (_, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        let g = grads.slot(0);
        assert_eq!(g.get(&Key::k1(0)).unwrap().as_scalar(), 0.0);
        assert_eq!(g.get(&Key::k1(1)).unwrap().as_scalar(), 1.0);
    }
}
