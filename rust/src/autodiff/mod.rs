//! Relational reverse-mode auto-differentiation (Sections 3–5).
//!
//! Two interchangeable modes:
//!
//! * **Eager** (`reverse::grad`) — Algorithm 2 executed directly: run the
//!   forward query capturing every intermediate relation (the tape), seed
//!   `∂Q/∂R_n = {(keyOut, 1)}`, then sweep the DAG in reverse topological
//!   order applying the per-operator relation-Jacobian products (`rjp`),
//!   accumulating multi-consumer contributions with `add`. The RJP joins
//!   and their trailing Σ are fused into single hash passes (the paper's
//!   join-agg-tree optimization applied unconditionally).
//!
//! * **Graph** (`graph::backward_graph`) — the source-to-source
//!   transformation the paper ships to the database optimizer: emit the
//!   backward computation as a *new functional-RA query* whose inputs are
//!   the seed gradient plus taped intermediates as constants. Section 4's
//!   rewrite optimizations (⋈const elision for ×/MatMul kernels,
//!   Σ elimination by join-cardinality analysis) are applied during
//!   construction; `optimize` holds the cardinality/key-solver machinery.
//!
//! Both modes are tested against each other and against central finite
//! differences (`check`).

pub mod check;
pub mod graph;
pub mod jacobian;
pub mod optimize;
pub mod reverse;
pub mod rjp;

pub use graph::{backward_graph, eval_backward, BackwardPlan};
pub use jacobian::{jacobian, partial_derivative, rjp_via_jacobian};
pub use reverse::{grad, grad_with_seed, grad_with_seed_wrt, grad_wrt, Gradients};
