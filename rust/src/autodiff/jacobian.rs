//! The *definitional* machinery of Section 3, implemented literally:
//!
//! * `partial_derivative` — §3.1's limit definition: build the perturbed
//!   relation `R_h` (`R_h[k] = h`, zero elsewhere), run the query on
//!   `R ⊞ R_h` via `⋈const(pred, proj, ⊗₁=+, R_h, τ(K_i))`, and difference
//!   against the unperturbed run with `⊗₂ = (valR − valL)/h`.
//! * `jacobian` — the relational Jacobian `J_Q : 𝔽(K_i) → 𝔽(K_i × K_o)`:
//!   one partial derivative per input key, keys concatenated.
//! * `rjp_via_jacobian` — §3.2's relation-Jacobian product formula
//!   `Σ(grp, ⊕, ⋈(pred, proj, ⊗, τ(K_o), J_Q))` as an *actual RA query*.
//!
//! These are O(|R|) query evaluations — far too slow for training, which
//! is the whole point of Section 4's closed-form RJPs. They exist to
//! *pin the semantics*: tests assert that Algorithm 2's output equals the
//! Jacobian-based gradient computed from the definitions alone.

use crate::kernels::{AggKernel, BinaryKernel, KernelBackend};
use crate::ra::eval::eval_query;
use crate::ra::expr::{Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
use crate::ra::{Chunk, Key, Relation};
use anyhow::{bail, Result};

/// §3.1: `∂Q/∂k` — how much each output tuple moves per unit change of
/// input tuple `k` (central difference; scalar-chunk relations only).
pub fn partial_derivative(
    q: &Query,
    inputs: &[&Relation],
    slot: usize,
    k: &Key,
    h: f32,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let perturbed =
        |delta: f32| -> Result<Relation> {
            // R ⊞ R_h expressed exactly as the paper's
            // ⋈const(pred, proj, ⊗₁=+, R_h, τ(K_i)) — with the engine's
            // outer-sum `add` standing in for the total map.
            let mut r = inputs[slot].clone();
            let mut found = false;
            for (kk, v) in r.iter_mut() {
                if kk == k {
                    if v.shape() != (1, 1) {
                        bail!("partial_derivative supports scalar chunks only");
                    }
                    *v = Chunk::scalar(v.as_scalar() + delta);
                    found = true;
                }
            }
            if !found {
                bail!("key {k} not present in input {slot}");
            }
            let mut ins: Vec<&Relation> = inputs.to_vec();
            ins[slot] = &r;
            eval_query(q, &ins, backend)
        };
    let plus = perturbed(h)?;
    let minus = perturbed(-h)?;
    // join the two runs on equal keys with ⊗₂ = (valL − valR) / 2h
    let mut out = Relation::with_capacity(plus.len());
    for (ko, vp) in plus.iter() {
        let vm = minus
            .get(ko)
            .ok_or_else(|| anyhow::anyhow!("perturbation changed the output key set at {ko}"))?;
        out.insert(*ko, vp.zip_map(vm, |a, b| (a - b) / (2.0 * h)));
    }
    Ok(out)
}

/// §3.1: the relational Jacobian `J_Q`, keyed `⟨k_in…, k_out…⟩`.
/// Zero entries (below `tol`) are omitted — relations are sparse.
pub fn jacobian(
    q: &Query,
    inputs: &[&Relation],
    slot: usize,
    h: f32,
    tol: f32,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut j = Relation::new();
    for (kin, _) in inputs[slot].iter() {
        let pd = partial_derivative(q, inputs, slot, kin, h, backend)?;
        for (kout, v) in pd.iter() {
            if v.as_scalar().abs() > tol {
                j.insert(kin.concat(kout), v.clone());
            }
        }
    }
    Ok(j)
}

/// §3.2: the relation-Jacobian product as an RA query —
/// `RJP_Q ≡ Σ(grp, ⊕, ⋈(pred, proj, ⊗, τ(K_o), J_Q))` with
/// `pred(keyL, keyR) ↦ keyL = keyR[in_arity..]`, `proj ↦ keyR`,
/// `grp(key) ↦ key[0..in_arity]`, `⊗ = ×`, `⊕ = +`.
pub fn rjp_via_jacobian(
    grad_out: &Relation,
    jac: &Relation,
    in_arity: usize,
    out_arity: usize,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut qb = QueryBuilder::new();
    let g = qb.scan(0, "dL_dOut");
    let j = qb.scan(1, "J_Q");
    // keyL (out key) matches the trailing components of the Jacobian key
    let pred = JoinPred::on((0..out_arity).map(|p| (p, in_arity + p)).collect());
    let joined = qb.join(
        pred,
        KeyProj2((0..in_arity + out_arity).map(Sel2::R).collect()),
        BinaryKernel::Mul,
        g,
        j,
    );
    let grp = KeyProj::take(&(0..in_arity).collect::<Vec<_>>());
    let s = qb.agg(grp, AggKernel::Sum, joined);
    let q = qb.finish(s);
    eval_query(&q, &[grad_out, jac], backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad;
    use crate::kernels::{NativeBackend, UnaryKernel};
    use crate::util::Prng;

    /// loss-ish query: y(i) = Σ_j x(i,j)², keyed output (not a scalar
    /// loss — Jacobians are defined for any query).
    fn sq_rowsum_query() -> Query {
        let mut qb = QueryBuilder::new();
        let s = qb.scan(0, "x");
        let sq = qb.map(UnaryKernel::Square, 2, s);
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, sq);
        qb.finish(a)
    }

    fn sample_input(rng: &mut Prng) -> Relation {
        let mut x = Relation::new();
        for i in 0..3i64 {
            for j in 0..2i64 {
                x.insert(Key::k2(i, j), Chunk::scalar(rng.uniform(-1.0, 1.0)));
            }
        }
        x
    }

    #[test]
    fn partial_derivative_matches_analytic() {
        let mut rng = Prng::new(301);
        let x = sample_input(&mut rng);
        let q = sq_rowsum_query();
        let k = Key::k2(1, 0);
        let pd = partial_derivative(&q, &[&x], 0, &k, 1e-2, &NativeBackend).unwrap();
        // ∂y(1)/∂x(1,0) = 2·x(1,0); all other outputs unaffected
        let want = 2.0 * x.get(&k).unwrap().as_scalar();
        assert!((pd.get(&Key::k1(1)).unwrap().as_scalar() - want).abs() < 1e-2);
        assert!(pd.get(&Key::k1(0)).unwrap().as_scalar().abs() < 1e-3);
    }

    #[test]
    fn jacobian_is_block_diagonal_for_rowsum() {
        let mut rng = Prng::new(302);
        let x = sample_input(&mut rng);
        let q = sq_rowsum_query();
        let j = jacobian(&q, &[&x], 0, 1e-2, 1e-3, &NativeBackend).unwrap();
        // Entries exist only where in-row == out-row.
        for (k, _) in j.iter() {
            assert_eq!(k.len(), 3); // ⟨i, j, i_out⟩
            assert_eq!(k.get(0), k.get(2), "off-diagonal Jacobian entry {k}");
        }
        // One entry per input tuple (each feeds exactly one output).
        assert_eq!(j.len(), x.len());
    }

    #[test]
    fn rjp_via_jacobian_equals_algorithm_2() {
        // The Section 3.2 definition and the Section 4/5 implementation
        // must agree: Σ(grp,+,⋈(τ(K_o), J_Q)) applied to the seed equals
        // Algorithm 2's gradient.
        let mut rng = Prng::new(303);
        let x = sample_input(&mut rng);
        // scalar loss: Σ_i y(i) … then gradient = RJP with seed {(⟨⟩,1)}…
        // use the keyed query directly with an all-ones seed instead.
        let q = sq_rowsum_query();
        let jac = jacobian(&q, &[&x], 0, 1e-2, 1e-4, &NativeBackend).unwrap();
        let out = eval_query(&q, &[&x], &NativeBackend).unwrap();
        let mut seed = Relation::new();
        for (k, _) in out.iter() {
            seed.insert(*k, Chunk::scalar(1.0));
        }
        let via_jac = rjp_via_jacobian(&seed, &jac, 2, 1, &NativeBackend).unwrap();
        let (_, grads) = grad(&q, &[&x], &NativeBackend).unwrap();
        assert!(
            via_jac.approx_eq(grads.slot(0), 2e-2),
            "definitional RJP {:?} vs Algorithm 2 {:?}",
            via_jac,
            grads.slot(0)
        );
    }

    #[test]
    fn missing_key_errors() {
        let mut rng = Prng::new(304);
        let x = sample_input(&mut rng);
        let q = sq_rowsum_query();
        assert!(
            partial_derivative(&q, &[&x], 0, &Key::k2(9, 9), 1e-2, &NativeBackend).is_err()
        );
    }
}
