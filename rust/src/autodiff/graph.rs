//! Graph mode: emit the backward pass as a *new functional-RA query*
//! (Section 5 / Fig. 5 — the form the paper hands to the database
//! optimizer), with Section 4's rewrite optimizations applied during
//! construction:
//!
//! * **⋈const elision** — for ⊗ ∈ {×, MatMul, …} the inner
//!   `⋈const(τ(K_l), R_r)` of the general RJP collapses: the backward
//!   join chains the upstream gradient directly against the taped other
//!   operand (`VjpSpec::ChainOther`).
//! * **Σ elimination** — the trailing Σ is dropped whenever the forward
//!   join's cardinality guarantees at most one match per input tuple
//!   (`optimize::backward_needs_agg`); kept on the 1-side of a 1-n join.
//! * **Join-agg-tree fusion** — an `Σ(grp, ⊕, ⋈(...))` pair is
//!   differentiated as one unit: the aggregation operator is never
//!   differentiated separately, and the wide pre-aggregation gradient
//!   relation is never materialized.
//!
//! The generated query's inputs are *scan slots*: slot 0 is the seed
//! gradient and slots 1.. are the taped forward intermediates it needs
//! (`tape_inputs` maps them back to forward nodes). Keeping tapes as
//! inputs — not embedded constants — lets the distributed executor feed
//! partitioned taped relations straight into the backward plan.

use super::optimize::{backward_join_pred, backward_needs_agg, compose_grp_proj, solve_side_key};
use crate::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel, VjpSpec};
use crate::ra::eval::{eval_query_tape, Tape};
use crate::ra::expr::{NodeId, Op, Query, QueryBuilder};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2, Sel, Sel2};
use crate::ra::Relation;
use crate::util::FxHashMap;
use anyhow::{bail, Context, Result};

/// A generated backward query: one DAG, one output per requested slot.
pub struct BackwardPlan {
    pub query: Query,
    /// (forward input slot, node in `query` computing its gradient).
    pub slot_outputs: Vec<(usize, NodeId)>,
    /// Forward node whose taped relation feeds backward scan slot `1+i`.
    pub tape_inputs: Vec<NodeId>,
}

impl BackwardPlan {
    /// Render the generated query (Fig. 5-style inspection).
    pub fn render(&self) -> String {
        let mut s = self.query.render();
        for (i, fwd) in self.tape_inputs.iter().enumerate() {
            s.push_str(&format!("slot {} = taped forward v{fwd}\n", i + 1));
        }
        for (slot, node) in &self.slot_outputs {
            s.push_str(&format!("∇ input slot {slot} = v{node}\n"));
        }
        s
    }

    /// Assemble the backward query's input list from a forward tape.
    pub fn inputs<'a>(&self, tape: &'a Tape, seed: &'a Relation) -> Vec<&'a Relation> {
        let mut ins: Vec<&Relation> = Vec::with_capacity(1 + self.tape_inputs.len());
        ins.push(seed);
        for &fwd in &self.tape_inputs {
            ins.push(&tape.rels[fwd]);
        }
        ins
    }
}

struct Builder {
    bb: QueryBuilder,
    arities: Vec<usize>,
    /// forward node -> backward scan node holding its taped relation
    tape_scans: FxHashMap<NodeId, NodeId>,
    tape_inputs: Vec<NodeId>,
}

impl Builder {
    /// Scan slot for the taped relation of forward node `fwd`.
    fn taped(&mut self, fwd: NodeId) -> NodeId {
        if let Some(&n) = self.tape_scans.get(&fwd) {
            return n;
        }
        let slot = 1 + self.tape_inputs.len();
        let n = self.bb.scan(slot, &format!("R{fwd}"));
        self.tape_scans.insert(fwd, n);
        self.tape_inputs.push(fwd);
        n
    }
}

/// Build the backward query for `q`. `in_arities` gives the key width of
/// each input slot; `slots` selects which inputs to differentiate.
pub fn backward_graph(q: &Query, in_arities: &[usize], slots: &[usize]) -> Result<BackwardPlan> {
    backward_graph_with(q, in_arities, slots, true)
}

/// As `backward_graph`, with the join-agg-tree fusion optimization
/// switchable — `fuse_join_agg = false` differentiates every Σ
/// separately (materializing the pre-aggregation gradient relation),
/// which is the paper's un-optimized construction. Used by the ablation
/// bench to quantify Section 4's rewrites.
pub fn backward_graph_with(
    q: &Query,
    in_arities: &[usize],
    slots: &[usize],
    fuse_join_agg: bool,
) -> Result<BackwardPlan> {
    let arities = node_arities(q, in_arities);
    let consumers = q.consumers();
    let needed = q.needed_for_slots(slots);
    let mut b = Builder {
        bb: QueryBuilder::new(),
        arities,
        tape_scans: FxHashMap::default(),
        tape_inputs: Vec::new(),
    };
    let mut grad_expr: Vec<Option<NodeId>> = vec![None; q.nodes.len()];
    let mut fused_grp: Vec<Option<KeyProj>> = vec![None; q.nodes.len()];

    let seed = b.bb.scan(0, "dL_dOut");
    grad_expr[q.output] = Some(seed);

    for i in (0..q.nodes.len()).rev() {
        let Some(g) = grad_expr[i] else { continue };
        let node = &q.nodes[i];
        match &node.op {
            Op::Scan { .. } | Op::Const { .. } => {}
            Op::Select { pred, proj, kernel } => {
                let child = node.children[0];
                if !needed[child] {
                    continue;
                }
                let gi = select_backward(&mut b, g, pred, proj, kernel, child)?;
                accumulate(&mut b.bb, &mut grad_expr[child], gi);
            }
            Op::Agg { grp, agg } => {
                if *agg != AggKernel::Sum {
                    bail!(
                        "graph-mode autodiff supports Σ with ⊕=+ only (got {})",
                        agg.name()
                    );
                }
                let child = node.children[0];
                if !needed[child] {
                    continue;
                }
                // Join-agg-tree fusion: differentiate Σ∘⋈ as one unit.
                // Kernels whose vjp needs both operands (Partial) are
                // excluded: their backward relies on the join's own output
                // keys, which the fused grp would collapse.
                let fusable = match &q.nodes[child].op {
                    Op::Join { kernel, .. } => {
                        !matches!(kernel.vjp_l(), VjpSpec::Partial { .. })
                            && !matches!(kernel.vjp_r(), VjpSpec::Partial { .. })
                    }
                    _ => false,
                };
                if fuse_join_agg
                    && fusable
                    && consumers[child].len() == 1
                    && grad_expr[child].is_none()
                {
                    fused_grp[child] = Some(grp.clone());
                    grad_expr[child] = Some(g);
                } else {
                    // General Σ backward: G ⋈ R_i on keyG = grp(keyIn).
                    let jp = JoinPred::left_eq_proj_of_right(grp);
                    let a = b.arities[child];
                    let ci = b.taped(child);
                    let gi = b.bb.join(jp, all_right(a), BinaryKernel::Fst, g, ci);
                    accumulate(&mut b.bb, &mut grad_expr[child], gi);
                }
            }
            Op::Join { pred, proj, kernel } => {
                let (cl, cr) = (node.children[0], node.children[1]);
                let grp = fused_grp[i]
                    .clone()
                    .unwrap_or_else(|| KeyProj::identity(proj.out_arity()));
                let grp_proj = compose_grp_proj(&grp, proj);
                for (is_left, this, other) in [(true, cl, cr), (false, cr, cl)] {
                    if !needed[this] {
                        continue; // off every requested gradient path
                    }
                    let vjp = if is_left { kernel.vjp_l() } else { kernel.vjp_r() };
                    let gi = join_side_backward(
                        &mut b, g, &grp_proj, pred, kernel, &vjp, this, other, cl, cr, is_left,
                    )
                    .with_context(|| {
                        format!(
                            "backward of ⋈ v{i} ({}) for {} side",
                            kernel.name(),
                            if is_left { "left" } else { "right" }
                        )
                    })?;
                    accumulate(&mut b.bb, &mut grad_expr[this], gi);
                }
            }
            Op::AddQ => {
                for ci_idx in 0..node.children.len() {
                    let child = q.nodes[i].children[ci_idx];
                    if !needed[child] {
                        continue;
                    }
                    // Restrict G to the keys the side produced.
                    let a = b.arities[child];
                    let jp = JoinPred::on((0..a).map(|p| (p, p)).collect());
                    let ct = b.taped(child);
                    let gi = b.bb.join(jp, all_right(a), BinaryKernel::Fst, g, ct);
                    accumulate(&mut b.bb, &mut grad_expr[child], gi);
                }
            }
        }
    }

    let mut slot_outputs = Vec::new();
    for &slot in slots {
        let scan = q.scan_node(slot);
        let gi = match grad_expr[scan] {
            Some(id) => {
                // Restrict to keys present in the input relation: a
                // gradient is defined at the input's key set (the paper's
                // relations are functions on K), but elided constructions
                // can emit mathematically-nonzero tuples outside it
                // (e.g. d loss / d edge-weight for absent edges).
                let a = b.arities[scan];
                let jp = JoinPred::on((0..a).map(|p| (p, p)).collect());
                let proj = KeyProj2((0..a).map(Sel2::L).collect());
                let ct = b.taped(scan);
                b.bb.join(jp, proj, BinaryKernel::Fst, id, ct)
            }
            None => {
                // Loss independent of this input: empty gradient.
                b.bb.constant(std::sync::Arc::new(Relation::new()), "zero")
            }
        };
        slot_outputs.push((slot, gi));
    }
    let last = *slot_outputs
        .iter()
        .map(|(_, id)| id)
        .max()
        .expect("no slots requested");
    Ok(BackwardPlan {
        query: b.bb.finish(last),
        slot_outputs,
        tape_inputs: b.tape_inputs,
    })
}

/// Evaluate a backward plan single-node: inputs = seed + taped relations.
pub fn eval_backward(
    plan: &BackwardPlan,
    tape: &Tape,
    seed: &Relation,
    backend: &dyn KernelBackend,
) -> Result<Vec<(usize, Relation)>> {
    let ins = plan.inputs(tape, seed);
    let btape = eval_query_tape(&plan.query, &ins, backend)?;
    Ok(plan
        .slot_outputs
        .iter()
        .map(|&(slot, id)| (slot, (*btape.rels[id]).clone()))
        .collect())
}

/// Backward of `σ(pred, proj, ⊙)`: `G ⋈ R_in` on `keyG = proj(keyIn)`
/// (plus the forward filter), chaining through ⊙'s derivative — the
/// Section 4 selection RJP verbatim.
fn select_backward(
    b: &mut Builder,
    g: NodeId,
    pred: &KeyPred,
    proj: &KeyProj,
    kernel: &UnaryKernel,
    child: NodeId,
) -> Result<NodeId> {
    let vjp = kernel
        .vjp_kernel()
        .ok_or_else(|| anyhow::anyhow!("unary kernel {} has no vjp", kernel.name()))?;
    let mut jp = JoinPred::left_eq_proj_of_right(proj);
    jp.r_lits.extend(pred.0.iter().copied());
    let a = b.arities[child];
    let ci = b.taped(child);
    Ok(b.bb.join(jp, all_right(a), vjp, g, ci))
}

/// Backward of one side of `Σ(grp) ∘ ⋈(pred, proj, ⊗)` (grp = identity
/// when there is no fused aggregation).
#[allow(clippy::too_many_arguments)]
fn join_side_backward(
    b: &mut Builder,
    g: NodeId,
    grp_proj: &KeyProj2,
    pred: &JoinPred,
    kernel: &BinaryKernel,
    vjp: &VjpSpec,
    this: NodeId,
    other: NodeId,
    cl: NodeId,
    cr: NodeId,
    is_left: bool,
) -> Result<NodeId> {
    let side_arity = b.arities[this];
    let other_arity = b.arities[other];
    let solved = solve_side_key(grp_proj, pred, side_arity, is_left).ok_or_else(|| {
        anyhow::anyhow!(
            "input key not recoverable from (output key, other side) — \
             general construction unsupported for this plan shape"
        )
    })?;
    let needs_agg = backward_needs_agg(
        pred,
        if is_left { side_arity } else { other_arity },
        if is_left { other_arity } else { side_arity },
        is_left,
    );
    let bpred = backward_join_pred(grp_proj, pred, is_left);

    // Emit `G ⋈ R_other` (+ optional Σ): the ⋈const-elided construction.
    let solved_sels = solved.0.clone();
    let build_joined = |b: &mut Builder, chain: BinaryKernel, g_first: bool| -> NodeId {
        let mut out_sels = solved_sels.clone();
        if needs_agg {
            out_sels.extend((0..other_arity).map(Sel2::R));
        }
        let cother = b.taped(other);
        let joined = if g_first {
            b.bb.join(bpred.clone(), KeyProj2(out_sels), chain, g, cother)
        } else {
            let mpred = mirror_pred(&bpred);
            let msels = KeyProj2(out_sels.into_iter().map(mirror_sel).collect());
            b.bb.join(mpred, msels, chain, cother, g)
        };
        if needs_agg {
            b.bb.agg(
                KeyProj::take(&(0..side_arity).collect::<Vec<_>>()),
                AggKernel::Sum,
                joined,
            )
        } else {
            joined
        }
    };

    Ok(match vjp {
        VjpSpec::ChainOther(k) => build_joined(b, *k, true),
        VjpSpec::ChainOtherRev(k) => build_joined(b, *k, false),
        VjpSpec::OfG(u) => {
            if !needs_agg && solved.0.iter().all(|s| !matches!(s, Sel2::R(_))) {
                // Pure selection over the gradient relation.
                let uproj = KeyProj(
                    solved
                        .0
                        .iter()
                        .map(|s| match s {
                            Sel2::L(i) => Sel::C(*i),
                            Sel2::Lit(v) => Sel::Lit(*v),
                            Sel2::R(_) => unreachable!(),
                        })
                        .collect(),
                );
                b.bb.select(KeyPred::always(), uproj, *u, g)
            } else {
                let chain = match u {
                    UnaryKernel::Id => BinaryKernel::Fst,
                    UnaryKernel::Neg => BinaryKernel::NegFst,
                    other => bail!("OfG chain kernel {} unsupported in graph mode", other.name()),
                };
                build_joined(b, chain, true)
            }
        }
        // General construction (elementwise kernels whose partial needs
        // both operands): P = R_l ⋈ R_r with the partial kernel, then
        // G ⋈ P with the elementwise chain. Requires unique match keys
        // (no Σ) — true for the 1-1 loss joins this arises in.
        VjpSpec::Partial { partial, chain } => {
            if needs_agg {
                bail!(
                    "partial-vjp kernel {} under a fan-out join is unsupported in graph mode",
                    kernel.name()
                );
            }
            if solved.0.iter().any(|s| matches!(s, Sel2::R(_))) {
                bail!(
                    "partial-vjp kernel {}: side key needs other-side components",
                    kernel.name()
                );
            }
            // Partial kernels are written f(l, r): preserve operand order.
            let partial_kernel = if is_left {
                *partial
            } else {
                // ∂⊗/∂r as f(l, r) — our kernel set names these
                // explicitly; only Div has a right-partial in practice.
                match kernel {
                    BinaryKernel::Div => BinaryKernel::DDivR,
                    other => bail!("no right-partial kernel for {}", other.name()),
                }
            };
            let nl = b.taped(cl);
            let nr = b.taped(cr);
            let p = b.bb.join(pred.clone(), grp_proj.clone(), partial_kernel, nl, nr);
            let garity = grp_proj.out_arity();
            let jp = JoinPred::on((0..garity).map(|i| (i, i)).collect());
            let out = KeyProj2(solved.0.clone());
            b.bb.join(jp, out, *chain, g, p)
        }
        VjpSpec::None => bail!("kernel {} has no vjp for this operand", kernel.name()),
    })
}

fn accumulate(bb: &mut QueryBuilder, slot: &mut Option<NodeId>, g: NodeId) {
    *slot = Some(match slot.take() {
        None => g,
        Some(prev) => bb.add(prev, g),
    });
}

fn all_right(arity: usize) -> KeyProj2 {
    KeyProj2((0..arity).map(Sel2::R).collect())
}

fn mirror_pred(p: &JoinPred) -> JoinPred {
    JoinPred {
        eqs: p.eqs.iter().map(|&(i, j)| (j, i)).collect(),
        l_lits: p.r_lits.clone(),
        r_lits: p.l_lits.clone(),
    }
}

fn mirror_sel(s: Sel2) -> Sel2 {
    match s {
        Sel2::L(i) => Sel2::R(i),
        Sel2::R(i) => Sel2::L(i),
        Sel2::Lit(v) => Sel2::Lit(v),
    }
}

/// Static key arity per node from the input-slot arities.
pub fn node_arities(q: &Query, in_arities: &[usize]) -> Vec<usize> {
    let mut out = vec![0usize; q.nodes.len()];
    for (i, node) in q.nodes.iter().enumerate() {
        out[i] = match &node.op {
            Op::Scan { slot, .. } => in_arities.get(*slot).copied().unwrap_or(0),
            Op::Const { rel, .. } => rel.key_arity().unwrap_or(0),
            Op::Select { proj, .. } => proj.out_arity(),
            Op::Join { proj, .. } => proj.out_arity(),
            Op::Agg { grp, .. } => grp.out_arity(),
            Op::AddQ => out[node.children[0]],
        };
    }
    out
}

/// Key arities of the input relations (helper for callers holding inputs).
pub fn input_arities(inputs: &[&Relation]) -> Vec<usize> {
    inputs.iter().map(|r| r.key_arity().unwrap_or(0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::check::finite_diff_grad;
    use crate::autodiff::grad;
    use crate::kernels::NativeBackend;
    use crate::ra::expr::matmul_query;
    use crate::ra::{Chunk, Key};
    use crate::util::Prng;

    fn ones_seed(rel: &Relation) -> Relation {
        let mut s = Relation::new();
        for (k, v) in rel.iter() {
            s.insert(*k, Chunk::filled(v.rows(), v.cols(), 1.0));
        }
        s
    }

    #[test]
    fn graph_matches_eager_on_blocked_matmul() {
        let mut rng = Prng::new(31);
        let mut a = Relation::new();
        let mut b = Relation::new();
        for i in 0..2i64 {
            for k in 0..3i64 {
                a.insert(Key::k2(i, k), Chunk::random(2, 2, &mut rng, 1.0));
            }
        }
        for k in 0..3i64 {
            for j in 0..2i64 {
                b.insert(Key::k2(k, j), Chunk::random(2, 2, &mut rng, 1.0));
            }
        }
        let q = matmul_query();
        let (tape, eager) = grad(&q, &[&a, &b], &NativeBackend).unwrap();
        let plan = backward_graph(&q, &input_arities(&[&a, &b]), &[0, 1]).unwrap();
        let seed = ones_seed(tape.output(&q));
        let got = eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap();
        for (slot, rel) in got {
            let want = eager.slot(slot);
            assert!(
                rel.approx_eq(want, 1e-4),
                "slot {slot}: graph {:?} vs eager {:?}",
                rel,
                want
            );
        }
    }

    #[test]
    fn fused_backward_references_no_wide_intermediate() {
        // With join-agg fusion, the backward query only scans 2-component
        // taped inputs — never the 3-component pre-aggregation join
        // output (Fig. 4's optimized RJP).
        let q = matmul_query();
        let plan = backward_graph(&q, &[2, 2], &[0, 1]).unwrap();
        let fwd_arities = node_arities(&q, &[2, 2]);
        for &fwd in &plan.tape_inputs {
            assert!(
                fwd_arities[fwd] <= 2,
                "backward plan scans wide taped node v{fwd}"
            );
        }
        // Σ is kept (matmul join is m-n: fan-out on both sides).
        assert!(plan.query.op_counts().get("Σ").copied().unwrap_or(0) >= 2);
    }

    #[test]
    fn graph_matches_finite_differences() {
        let mut rng = Prng::new(33);
        let a = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::random(2, 2, &mut rng, 1.0)),
            (Key::k2(0, 1), Chunk::random(2, 2, &mut rng, 1.0)),
        ]);
        let b = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::random(2, 2, &mut rng, 1.0)),
            (Key::k2(1, 0), Chunk::random(2, 2, &mut rng, 1.0)),
        ]);
        let mut qb = QueryBuilder::new();
        let sa = qb.scan(0, "A");
        let sb = qb.scan(1, "B");
        let j = qb.join(
            JoinPred::on(vec![(1, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::MatMul,
            sa,
            sb,
        );
        let s = qb.agg(KeyProj::take(&[0, 2]), AggKernel::Sum, j);
        let sums = qb.map(UnaryKernel::SumAll, 2, s);
        let loss = qb.agg(KeyProj::to_empty(), AggKernel::Sum, sums);
        let q = qb.finish(loss);

        let tape = crate::ra::eval::eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap();
        let plan = backward_graph(&q, &input_arities(&[&a, &b]), &[0]).unwrap();
        let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
        let got = eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap();
        let numeric = finite_diff_grad(&q, &[&a, &b], 0, 1e-2, &NativeBackend).unwrap();
        crate::autodiff::check::assert_grad_close(&got[0].1, &numeric, 5e-2);
    }

    #[test]
    fn one_to_one_join_backward_has_no_agg() {
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(2.0))]);
        let y = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(3.0))]);
        let mut qb = QueryBuilder::new();
        let sx = qb.scan(0, "x");
        let sy = qb.scan(1, "y");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::Mul,
            sx,
            sy,
        );
        let s = qb.agg(KeyProj::to_empty(), AggKernel::Sum, j);
        let q = qb.finish(s);
        let tape = crate::ra::eval::eval_query_tape(&q, &[&x, &y], &NativeBackend).unwrap();
        let plan = backward_graph(&q, &[1, 1], &[0, 1]).unwrap();
        assert_eq!(plan.query.op_counts().get("Σ").copied().unwrap_or(0), 0);
        let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
        let got = eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap();
        assert_eq!(got[0].1.get(&Key::k1(0)).unwrap().as_scalar(), 3.0);
        assert_eq!(got[1].1.get(&Key::k1(0)).unwrap().as_scalar(), 2.0);
    }

    #[test]
    fn ablation_unfused_matches_fused_but_materializes_wide_grad() {
        // Section 4's join-agg fusion: same gradients, but the unfused
        // plan scans the 3-component pre-aggregation join output that the
        // fused plan never touches.
        let mut rng = Prng::new(35);
        let mut a = Relation::new();
        let mut b = Relation::new();
        for i in 0..2i64 {
            for k in 0..2i64 {
                a.insert(Key::k2(i, k), Chunk::random(2, 2, &mut rng, 1.0));
                b.insert(Key::k2(k, i), Chunk::random(2, 2, &mut rng, 1.0));
            }
        }
        let q = matmul_query();
        let tape = crate::ra::eval::eval_query_tape(&q, &[&a, &b], &NativeBackend).unwrap();
        let seed = ones_seed(tape.output(&q));
        let fused = backward_graph_with(&q, &[2, 2], &[0, 1], true).unwrap();
        let unfused = backward_graph_with(&q, &[2, 2], &[0, 1], false).unwrap();
        let gf = eval_backward(&fused, &tape, &seed, &NativeBackend).unwrap();
        let gu = eval_backward(&unfused, &tape, &seed, &NativeBackend).unwrap();
        for (f, u) in gf.iter().zip(gu.iter()) {
            assert_eq!(f.0, u.0);
            assert!(f.1.approx_eq(&u.1, 1e-4), "slot {} fused≠unfused", f.0);
        }
        let fwd_arities = node_arities(&q, &[2, 2]);
        let fused_max = fused.tape_inputs.iter().map(|&n| fwd_arities[n]).max().unwrap();
        let unfused_max = unfused.tape_inputs.iter().map(|&n| fwd_arities[n]).max().unwrap();
        assert!(fused_max <= 2, "fused plan scans a wide intermediate");
        assert_eq!(unfused_max, 3, "unfused plan must scan the join output");
        // and the unfused plan is strictly larger
        assert!(unfused.query.len() > fused.query.len());
    }

    #[test]
    fn div_right_partial_supported() {
        // z = x / y elementwise; dz/dy = -x/y² — exercises the general
        // (non-elided) construction on the right operand.
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(6.0))]);
        let y = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(2.0))]);
        let mut qb = QueryBuilder::new();
        let sx = qb.scan(0, "x");
        let sy = qb.scan(1, "y");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::Div,
            sx,
            sy,
        );
        let s = qb.agg(KeyProj::to_empty(), AggKernel::Sum, j);
        let q = qb.finish(s);
        let tape = crate::ra::eval::eval_query_tape(&q, &[&x, &y], &NativeBackend).unwrap();
        let plan = backward_graph(&q, &[1, 1], &[0, 1]).unwrap();
        let seed = Relation::from_pairs(vec![(Key::empty(), Chunk::scalar(1.0))]);
        let got = eval_backward(&plan, &tape, &seed, &NativeBackend).unwrap();
        assert!((got[0].1.get(&Key::k1(0)).unwrap().as_scalar() - 0.5).abs() < 1e-6);
        assert!((got[1].1.get(&Key::k1(0)).unwrap().as_scalar() + 1.5).abs() < 1e-6);
    }
}
