//! Plan-level analysis backing Section 4's RJP optimizations:
//!
//! * **Join cardinality** (`join_cardinality`): classify a join as 1-1,
//!   1-n, n-1 or m-n from its predicate and the operands' key arities.
//!   Relation keys are unique, so if the predicate's equalities pin every
//!   component of one side's key, each tuple of the *other* side matches
//!   at most one tuple of that side. This drives "the Σ can be optimized
//!   out" for the n-side of a join RJP.
//!
//! * **Key solving** (`solve_side_key`): express an input key of a
//!   (join ∘ agg) pattern as component selections over (output key,
//!   other-side key), which is what lets the backward query be emitted as
//!   a single join `G ⋈ R_other` instead of the general three-relation
//!   construction.

use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel, Sel2};

/// Cardinality of a join from the perspective left-to-right.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinCard {
    /// Each left tuple matches ≤ 1 right tuple and vice versa.
    OneOne,
    /// Each left tuple may match many right tuples; each right tuple
    /// matches ≤ 1 left tuple.
    OneMany,
    /// Mirror of `OneMany`.
    ManyOne,
    /// No uniqueness either way.
    ManyMany,
}

/// Classify the join: `l_arity`/`r_arity` are the key widths of the
/// operand relations.
pub fn join_cardinality(pred: &JoinPred, l_arity: usize, r_arity: usize) -> JoinCard {
    let l_pinned = side_pinned(
        l_arity,
        pred.eqs.iter().map(|&(i, _)| i),
        pred.l_lits.iter().map(|&(i, _)| i),
    );
    let r_pinned = side_pinned(
        r_arity,
        pred.eqs.iter().map(|&(_, j)| j),
        pred.r_lits.iter().map(|&(j, _)| j),
    );
    match (l_pinned, r_pinned) {
        (true, true) => JoinCard::OneOne,
        // right key fully determined by the predicate ⇒ each left tuple
        // matches at most one right tuple ⇒ many(left)-one(right).
        (false, true) => JoinCard::ManyOne,
        (true, false) => JoinCard::OneMany,
        (false, false) => JoinCard::ManyMany,
    }
}

fn side_pinned(
    arity: usize,
    eq_comps: impl Iterator<Item = usize>,
    lit_comps: impl Iterator<Item = usize>,
) -> bool {
    let mut covered = vec![false; arity];
    for i in eq_comps.chain(lit_comps) {
        if i < arity {
            covered[i] = true;
        }
    }
    covered.into_iter().all(|c| c)
}

/// Does the backward pass for the given side need a trailing Σ?
///
/// The gradient of a left tuple is `Σ over matching (out-key, right-key)
/// pairs` — the Σ collapses when each left tuple participates in at most
/// one match, i.e. when the join is Many-One (right side pinned).
pub fn backward_needs_agg(pred: &JoinPred, l_arity: usize, r_arity: usize, for_left: bool) -> bool {
    match join_cardinality(pred, l_arity, r_arity) {
        JoinCard::OneOne => false,
        JoinCard::ManyOne => !for_left,  // left side: ≤1 match each
        JoinCard::OneMany => for_left,   // right side: ≤1 match each
        JoinCard::ManyMany => true,
    }
}

/// Solve for the components of one side's key in terms of the *post-agg
/// output key* and the other side's key.
///
/// Forward pattern: `out = grp(proj(kl, kr))` with matches constrained by
/// `pred(kl, kr)`. `grp_proj = grp ∘ proj` is given pre-composed as a
/// `KeyProj2`. Returns, for each component of the solved side's key, a
/// selector over (L = gradient/out key, R = other side's key) — or `None`
/// if some component is unrecoverable (the general fallback construction
/// must be used).
pub fn solve_side_key(
    grp_proj: &KeyProj2,
    pred: &JoinPred,
    side_arity: usize,
    solve_left: bool,
) -> Option<KeyProj2> {
    let mut out = Vec::with_capacity(side_arity);
    for comp in 0..side_arity {
        // 1) present in the output key?
        let from_out = grp_proj.0.iter().position(|s| match (solve_left, s) {
            (true, Sel2::L(i)) => *i == comp,
            (false, Sel2::R(i)) => *i == comp,
            _ => false,
        });
        if let Some(p) = from_out {
            out.push(Sel2::L(p)); // L = gradient key in the backward join
            continue;
        }
        // 2) equated to a component of the other side by the predicate?
        let from_other = pred.eqs.iter().find_map(|&(i, j)| {
            if solve_left && i == comp {
                Some(j)
            } else if !solve_left && j == comp {
                Some(i)
            } else {
                None
            }
        });
        if let Some(j) = from_other {
            // Prefer reading it back out of the output key if the other
            // side's equated component survived the projection (keeps the
            // selector gradient-key-only, which the Partial construction
            // requires).
            let via_out = grp_proj.0.iter().position(|s| match (solve_left, s) {
                (true, Sel2::R(i)) => *i == j,
                (false, Sel2::L(i)) => *i == j,
                _ => false,
            });
            match via_out {
                Some(p) => out.push(Sel2::L(p)),
                None => out.push(Sel2::R(j)), // R = other side's key
            }
            continue;
        }
        // 3) pinned to a literal?
        let lits = if solve_left { &pred.l_lits } else { &pred.r_lits };
        if let Some(&(_, v)) = lits.iter().find(|&&(i, _)| i == comp) {
            out.push(Sel2::Lit(v));
            continue;
        }
        return None;
    }
    Some(KeyProj2(out))
}

/// Compose `grp ∘ proj` into a single binary projection.
pub fn compose_grp_proj(grp: &KeyProj, proj: &KeyProj2) -> KeyProj2 {
    KeyProj2(
        grp.0
            .iter()
            .map(|s| match *s {
                Sel::C(i) => proj.0[i],
                Sel::Lit(v) => Sel2::Lit(v),
            })
            .collect(),
    )
}

/// The backward join's predicate between the gradient relation (keyed by
/// the forward output keys, LEFT side) and the other operand (RIGHT side):
/// derived from where the other side's components appear in `grp_proj`,
/// plus the forward predicate's literal constraints on the other side.
pub fn backward_join_pred(grp_proj: &KeyProj2, pred: &JoinPred, other_is_right: bool) -> JoinPred {
    let mut jp = JoinPred::default();
    for (p, s) in grp_proj.0.iter().enumerate() {
        match (other_is_right, s) {
            (true, Sel2::R(j)) => jp.eqs.push((p, *j)),
            (false, Sel2::L(i)) => jp.eqs.push((p, *i)),
            (_, Sel2::Lit(v)) => jp.l_lits.push((p, *v)),
            _ => {}
        }
    }
    // Transitive equalities: if the gradient key carries this side's
    // component i (via grp_proj) and the forward predicate equates it to
    // the other side's component j, then G[p] = other[j] — without this
    // the backward join degenerates to a cross product whenever grp_proj
    // only kept this-side components.
    for &(i, j) in &pred.eqs {
        let (this_comp, other_comp) = if other_is_right { (i, j) } else { (j, i) };
        let pos = grp_proj.0.iter().position(|s| match (other_is_right, s) {
            (true, Sel2::L(c)) => *c == this_comp,
            (false, Sel2::R(c)) => *c == this_comp,
            _ => false,
        });
        if let Some(p) = pos {
            if !jp.eqs.contains(&(p, other_comp)) {
                jp.eqs.push((p, other_comp));
            }
        }
    }
    let other_lits = if other_is_right {
        &pred.r_lits
    } else {
        &pred.l_lits
    };
    jp.r_lits.extend(other_lits.iter().copied());
    jp
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The blocked-matmul join: A(i,k) ⋈ B(k,j) on L[1]=R[0],
    /// proj ⟨L0,L1,R1⟩, grp ⟨k0,k2⟩ ⇒ out (i,j).
    fn matmul_parts() -> (JoinPred, KeyProj2, KeyProj) {
        (
            JoinPred::on(vec![(1, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            KeyProj::take(&[0, 2]),
        )
    }

    #[test]
    fn matmul_join_is_many_many() {
        let (pred, _, _) = matmul_parts();
        assert_eq!(join_cardinality(&pred, 2, 2), JoinCard::ManyMany);
        assert!(backward_needs_agg(&pred, 2, 2, true));
    }

    #[test]
    fn row_join_is_many_one() {
        // X(i,k) ⋈ Θ(k): pred L[1]=R[0], Θ key fully pinned.
        let pred = JoinPred::on(vec![(1, 0)]);
        assert_eq!(join_cardinality(&pred, 2, 1), JoinCard::ManyOne);
        // backward for X needs no Σ; backward for Θ does (the paper's
        // "for the 1 side, the Σ must be kept").
        assert!(!backward_needs_agg(&pred, 2, 1, true));
        assert!(backward_needs_agg(&pred, 2, 1, false));
    }

    #[test]
    fn one_one_join() {
        let pred = JoinPred::on(vec![(0, 0)]);
        assert_eq!(join_cardinality(&pred, 1, 1), JoinCard::OneOne);
        assert!(!backward_needs_agg(&pred, 1, 1, true));
        assert!(!backward_needs_agg(&pred, 1, 1, false));
    }

    #[test]
    fn solve_matmul_left_key() {
        // dA keyed (i,k): i from out key comp 0, k from B's key comp 0.
        let (pred, proj, grp) = matmul_parts();
        let gp = compose_grp_proj(&grp, &proj);
        assert_eq!(gp, KeyProj2(vec![Sel2::L(0), Sel2::R(1)]));
        let solved = solve_side_key(&gp, &pred, 2, true).unwrap();
        assert_eq!(solved, KeyProj2(vec![Sel2::L(0), Sel2::R(0)]));
        // dB keyed (k,j): k from A's comp 1, j from out comp 1.
        let solved_r = solve_side_key(&gp, &pred, 2, false).unwrap();
        assert_eq!(solved_r, KeyProj2(vec![Sel2::R(1), Sel2::L(1)]));
    }

    #[test]
    fn solve_fails_when_component_dropped() {
        // proj drops L[1] and pred doesn't mention it: unsolvable.
        let pred = JoinPred::on(vec![(0, 0)]);
        let gp = KeyProj2(vec![Sel2::L(0)]);
        assert!(solve_side_key(&gp, &pred, 2, true).is_none());
        assert!(solve_side_key(&gp, &pred, 1, true).is_some());
    }

    #[test]
    fn backward_pred_for_matmul() {
        // G keyed (i,j); other side = B keyed (k,j): join on G[1]=B[1].
        let (pred, proj, grp) = matmul_parts();
        let gp = compose_grp_proj(&grp, &proj);
        let bp = backward_join_pred(&gp, &pred, true);
        assert_eq!(bp.eqs, vec![(1, 1)]);
        // other side = A keyed (i,k): join on G[0]=A[0].
        let bp_l = backward_join_pred(&gp, &pred, false);
        assert_eq!(bp_l.eqs, vec![(0, 0)]);
    }

    #[test]
    fn literal_constraints_propagate() {
        let mut pred = JoinPred::on(vec![(0, 0)]);
        pred.r_lits.push((1, 3));
        let gp = KeyProj2(vec![Sel2::L(0), Sel2::R(1)]);
        let bp = backward_join_pred(&gp, &pred, true);
        // direct (G[1]=R[1] via grp_proj) + transitive (G[0]=L[0]=R[0])
        assert_eq!(bp.eqs, vec![(1, 1), (0, 0)]);
        assert_eq!(bp.r_lits, vec![(1, 3)]);
        let solved = solve_side_key(&gp, &pred, 1, true).unwrap();
        assert_eq!(solved, KeyProj2(vec![Sel2::L(0)]));
    }
}
