//! Eager relation-Jacobian products, one per RA operator (Section 4).
//!
//! Each function takes the upstream gradient `∂Q/∂R_j` (keyed by the
//! operator's *output* key set) plus the taped input relation(s) and
//! produces `∂Q/∂R_i` (keyed by the operator's *input* key set). The
//! trailing `Σ(grp, ⊕, …)` of the paper's join construction is fused into
//! the `merge_add` accumulation.

use crate::kernels::{AggKernel, BinaryKernel, KernelBackend, UnaryKernel, VjpSpec};
use crate::ra::funcs::{JoinPred, KeyPred, KeyProj, KeyProj2};
use crate::ra::{Chunk, Key, Relation};
use crate::util::FxHashMap;
use anyhow::{bail, Result};

/// Apply a `VjpSpec` for one operand of a binary kernel.
/// `g` = upstream gradient chunk, `this`/`other` = the operand values.
pub fn apply_vjp(
    spec: &VjpSpec,
    backend: &dyn KernelBackend,
    key: &Key,
    g: &Chunk,
    this: &Chunk,
    other: &Chunk,
    is_left: bool,
) -> Result<Chunk> {
    Ok(match spec {
        VjpSpec::ChainOther(k) => backend.binary(k, key, g, other),
        VjpSpec::ChainOtherRev(k) => backend.binary(k, key, other, g),
        VjpSpec::Partial { partial, chain } => {
            // partial kernels are written as f(l, r) regardless of side
            let (l, r) = if is_left { (this, other) } else { (other, this) };
            let p = backend.binary(partial, key, l, r);
            backend.binary(chain, key, g, &p)
        }
        VjpSpec::OfG(u) => backend.unary(u, key, g),
        VjpSpec::None => bail!("kernel has no vjp for this operand"),
    })
}

/// RJP for `τ(K)`: `(R_o, R_i) ↦ R_o` — the table scan returns its input,
/// so its Jacobian is the identity.
pub fn rjp_scan(grad_out: &Relation) -> Relation {
    grad_out.clone()
}

/// RJP for `σ(pred, proj, ⊙, ·)`: join the upstream gradient with the
/// taped input on `keyG = proj(keyIn)`, chaining through `⊙`'s derivative.
/// Tuples rejected by `pred` never joined forward, so their gradient is
/// implicitly zero — exactly the paper's remark.
pub fn rjp_select(
    pred: &KeyPred,
    proj: &KeyProj,
    kernel: &UnaryKernel,
    grad_out: &Relation,
    input: &Relation,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let vjp = match kernel.vjp_kernel() {
        Some(v) => v,
        None => bail!("unary kernel {} has no vjp", kernel.name()),
    };
    let mut out = Relation::with_capacity(grad_out.len());
    for (k, v) in input.iter() {
        if !pred.matches(k) {
            continue;
        }
        let ko = proj.apply(k);
        if let Some(g) = grad_out.get(&ko) {
            // vjp kernels are keyed by the *input* tuple key (dropout
            // masks must match the forward application).
            out.insert(*k, backend.binary(&vjp, k, g, v));
        }
    }
    Ok(out)
}

/// RJP for `Σ(grp, ⊕, ·)`.
pub fn rjp_agg(
    grp: &KeyProj,
    agg: &AggKernel,
    grad_out: &Relation,
    input: &Relation,
    agg_out: &Relation,
    _backend: &dyn KernelBackend,
) -> Result<Relation> {
    let mut out = Relation::with_capacity(input.len());
    for (k, v) in input.iter() {
        let ko = grp.apply(k);
        if let Some(g) = grad_out.get(&ko) {
            let gv = match agg {
                // ∂(Σx)/∂x = 1 ⇒ gradient passes through unchanged.
                AggKernel::Sum => g.clone(),
                // Subgradient: route to the elements equal to the max.
                AggKernel::Max => {
                    let m = agg_out
                        .get(&ko)
                        .expect("agg output missing taped group value");
                    let ind = v.zip_map(m, |x, mx| if x >= mx { 1.0 } else { 0.0 });
                    g.zip_map(&ind, |a, b| a * b)
                }
            };
            out.insert(*k, gv);
        }
    }
    Ok(out)
}

/// Gradients for the two sides of a join, produced in one pass.
pub struct JoinGrads {
    pub left: Option<Relation>,
    pub right: Option<Relation>,
}

/// RJP for `⋈(pred, proj, ⊗, ·, ·)` (and `⋈const`, by passing
/// `want_left`/`want_right` = false for the constant side).
///
/// Re-runs the forward hash-join match over the taped inputs; for every
/// matched pair whose output key carries a gradient, chains through ⊗'s
/// vjp and accumulates with `merge_add` — the fused form of the paper's
/// `Σ(grp, ⊕, ⋈(pred₁, proj₁, ⊗₁, τ(K_o), ⋈const(pred₂, proj₂, ⊗₂, …)))`.
#[allow(clippy::too_many_arguments)]
pub fn rjp_join(
    pred: &JoinPred,
    proj: &KeyProj2,
    kernel: &BinaryKernel,
    grad_out: &Relation,
    left: &Relation,
    right: &Relation,
    want_left: bool,
    want_right: bool,
    backend: &dyn KernelBackend,
) -> Result<JoinGrads> {
    let mut gl = want_left.then(Relation::new);
    let mut gr = want_right.then(Relation::new);
    let (vl, vr) = (kernel.vjp_l(), kernel.vjp_r());
    if want_left && vl == VjpSpec::None {
        bail!("kernel {} has no left vjp", kernel.name());
    }
    if want_right && vr == VjpSpec::None {
        bail!("kernel {} has no right vjp", kernel.name());
    }

    // Hash the right side on its equality components (mirrors eval's join).
    let rcomps = pred.right_comps();
    let lcomps = pred.left_comps();
    let mut table: FxHashMap<Key, Vec<u32>> = FxHashMap::default();
    for (idx, (rk, _)) in right.iter().enumerate() {
        if !pred.r_lits.iter().all(|&(j, v)| rk.get(j) == v) {
            continue;
        }
        table.entry(subkey(rk, &rcomps)).or_default().push(idx as u32);
    }
    for (lk, lv) in left.iter() {
        if !pred.l_lits.iter().all(|&(i, v)| lk.get(i) == v) {
            continue;
        }
        let Some(matches) = table.get(&subkey(lk, &lcomps)) else {
            continue;
        };
        for &ri in matches {
            let (rk, rv) = &right.pairs()[ri as usize];
            let ko = proj.apply(lk, rk);
            let Some(g) = grad_out.get(&ko) else { continue };
            if let Some(gl) = gl.as_mut() {
                gl.merge_add(*lk, apply_vjp(&vl, backend, lk, g, lv, rv, true)?);
            }
            if let Some(gr) = gr.as_mut() {
                gr.merge_add(*rk, apply_vjp(&vr, backend, rk, g, rv, lv, false)?);
            }
        }
    }
    Ok(JoinGrads {
        left: gl,
        right: gr,
    })
}

/// RJP for `add(·, ·)`: the gradient passes through to each side,
/// restricted to the keys the side actually produced (`add` treats a
/// missing key as zero, whose gradient stays zero).
pub fn rjp_add(grad_out: &Relation, side_input: &Relation) -> Relation {
    let mut out = Relation::with_capacity(side_input.len());
    for (k, _) in side_input.iter() {
        if let Some(g) = grad_out.get(k) {
            out.insert(*k, g.clone());
        }
    }
    out
}

#[inline]
fn subkey(k: &Key, comps: &[usize]) -> Key {
    let mut out = Key::empty();
    for &c in comps {
        out = out.push(k.get(c));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::NativeBackend;
    use crate::ra::funcs::Sel2;

    #[test]
    fn scan_rjp_is_identity() {
        let g = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(2.0))]);
        assert!(rjp_scan(&g).approx_eq(&g, 0.0));
    }

    #[test]
    fn select_rjp_logistic() {
        // y = logistic(x); dL/dx = g * y(1-y)
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(0.0))]);
        let g = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(4.0))]);
        let out = rjp_select(
            &KeyPred::always(),
            &KeyProj::identity(1),
            &UnaryKernel::Logistic,
            &g,
            &x,
            &NativeBackend,
        )
        .unwrap();
        // σ(0)=0.5 ⇒ derivative 0.25 ⇒ grad 1.0
        assert!((out.get(&Key::k1(0)).unwrap().as_scalar() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn select_rjp_filtered_tuples_get_zero() {
        let x = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(1.0)),
        ]);
        let g = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(1.0)),
            (Key::k1(1), Chunk::scalar(1.0)),
        ]);
        let out = rjp_select(
            &KeyPred::eq_lit(0, 0),
            &KeyProj::identity(1),
            &UnaryKernel::Id,
            &g,
            &x,
            &NativeBackend,
        )
        .unwrap();
        // filtered tuple ⟨1⟩ absent from gradient = implicit zero
        assert_eq!(out.len(), 1);
        assert!(out.get(&Key::k1(1)).is_none());
    }

    #[test]
    fn agg_sum_rjp_broadcasts_gradient() {
        let x = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::scalar(1.0)),
            (Key::k2(0, 1), Chunk::scalar(2.0)),
            (Key::k2(1, 0), Chunk::scalar(3.0)),
        ]);
        let g = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(10.0)),
            (Key::k1(1), Chunk::scalar(20.0)),
        ]);
        let out = rjp_agg(
            &KeyProj::take(&[0]),
            &AggKernel::Sum,
            &g,
            &x,
            &Relation::new(),
            &NativeBackend,
        )
        .unwrap();
        assert_eq!(out.get(&Key::k2(0, 1)).unwrap().as_scalar(), 10.0);
        assert_eq!(out.get(&Key::k2(1, 0)).unwrap().as_scalar(), 20.0);
    }

    #[test]
    fn join_rjp_mul_scalar() {
        // z(k) = x(k) * y(k); dz/dx = g*y, dz/dy = g*x
        let x = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(3.0))]);
        let y = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(5.0))]);
        let g = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(2.0))]);
        let jg = rjp_join(
            &JoinPred::on(vec![(0, 0)]),
            &KeyProj2(vec![Sel2::L(0)]),
            &BinaryKernel::Mul,
            &g,
            &x,
            &y,
            true,
            true,
            &NativeBackend,
        )
        .unwrap();
        assert_eq!(jg.left.unwrap().get(&Key::k1(0)).unwrap().as_scalar(), 10.0);
        assert_eq!(jg.right.unwrap().get(&Key::k1(0)).unwrap().as_scalar(), 6.0);
    }

    #[test]
    fn join_rjp_accumulates_fanout() {
        // one x joins many y: dx = Σ_j g_j * y_j  (the Σ the paper keeps
        // on the 1-side of a 1-n join)
        let x = Relation::from_pairs(vec![(Key::k1(7), Chunk::scalar(2.0))]);
        let y = Relation::from_pairs(vec![
            (Key::k2(7, 0), Chunk::scalar(1.0)),
            (Key::k2(7, 1), Chunk::scalar(10.0)),
        ]);
        let g = Relation::from_pairs(vec![
            (Key::k2(7, 0), Chunk::scalar(1.0)),
            (Key::k2(7, 1), Chunk::scalar(1.0)),
        ]);
        let jg = rjp_join(
            &JoinPred::on(vec![(0, 0)]),
            &KeyProj2(vec![Sel2::R(0), Sel2::R(1)]),
            &BinaryKernel::Mul,
            &g,
            &x,
            &y,
            true,
            false,
            &NativeBackend,
        )
        .unwrap();
        assert_eq!(jg.left.unwrap().get(&Key::k1(7)).unwrap().as_scalar(), 11.0);
        assert!(jg.right.is_none());
    }

    #[test]
    fn join_rjp_matmul_blocks() {
        // Z = A·B ⇒ dA = g·Bᵀ, dB = Aᵀ·g (per matched block pair)
        let mut rng = crate::util::Prng::new(5);
        let a = Chunk::random(3, 4, &mut rng, 1.0);
        let b = Chunk::random(4, 2, &mut rng, 1.0);
        let g = Chunk::random(3, 2, &mut rng, 1.0);
        let ra = Relation::from_pairs(vec![(Key::k2(0, 0), a.clone())]);
        let rb = Relation::from_pairs(vec![(Key::k2(0, 0), b.clone())]);
        let rg = Relation::from_pairs(vec![(Key::k3(0, 0, 0), g.clone())]);
        let jg = rjp_join(
            &JoinPred::on(vec![(1, 0)]),
            &KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            &BinaryKernel::MatMul,
            &rg,
            &ra,
            &rb,
            true,
            true,
            &NativeBackend,
        )
        .unwrap();
        let da = jg.left.unwrap();
        let db = jg.right.unwrap();
        let want_da = crate::kernels::native::matmul_nt(&g, &b);
        let want_db = crate::kernels::native::matmul_tn(&a, &g);
        assert!(da.get(&Key::k2(0, 0)).unwrap().approx_eq(&want_da, 1e-5));
        assert!(db.get(&Key::k2(0, 0)).unwrap().approx_eq(&want_db, 1e-5));
    }

    #[test]
    fn add_rjp_restricts_keys() {
        let side = Relation::from_pairs(vec![(Key::k1(0), Chunk::scalar(1.0))]);
        let g = Relation::from_pairs(vec![
            (Key::k1(0), Chunk::scalar(5.0)),
            (Key::k1(1), Chunk::scalar(7.0)),
        ]);
        let out = rjp_add(&g, &side);
        assert_eq!(out.len(), 1);
        assert_eq!(out.get(&Key::k1(0)).unwrap().as_scalar(), 5.0);
    }
}
