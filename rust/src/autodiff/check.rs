//! Gradient checking: central finite differences against the relational
//! partial-derivative *definition* of Section 3.1 (perturb one key's value
//! by ±h, re-run the query, difference the scalar loss).

use crate::kernels::KernelBackend;
use crate::ra::eval::eval_query;
use crate::ra::expr::Query;
use crate::ra::{Chunk, Relation};
use anyhow::{bail, Result};

/// Evaluate a scalar-loss query (output must be a single 1×1 tuple).
pub fn eval_loss(q: &Query, inputs: &[&Relation], backend: &dyn KernelBackend) -> Result<f32> {
    let out = eval_query(q, inputs, backend)?;
    if out.len() != 1 {
        bail!("loss query produced {} tuples, expected 1", out.len());
    }
    let loss = out.iter().next().unwrap().1.as_scalar();
    Ok(loss)
}

/// Numerically estimate `∂loss/∂inputs[slot]` element by element. O(|R|·d²)
/// query evaluations — only for tests on tiny relations.
pub fn finite_diff_grad(
    q: &Query,
    inputs: &[&Relation],
    slot: usize,
    h: f32,
    backend: &dyn KernelBackend,
) -> Result<Relation> {
    let base = inputs[slot];
    let mut grad = Relation::with_capacity(base.len());
    for (key, chunk) in base.iter() {
        let (rows, cols) = chunk.shape();
        let mut gchunk = Chunk::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                let mut plus = base.clone();
                let mut minus = base.clone();
                {
                    let pc = plus.iter_mut().find(|(k, _)| k == key).unwrap();
                    pc.1.set(r, c, chunk.at(r, c) + h);
                    let mc = minus.iter_mut().find(|(k, _)| k == key).unwrap();
                    mc.1.set(r, c, chunk.at(r, c) - h);
                }
                let lp = eval_with_replaced(q, inputs, slot, &plus, backend)?;
                let lm = eval_with_replaced(q, inputs, slot, &minus, backend)?;
                gchunk.set(r, c, (lp - lm) / (2.0 * h));
            }
        }
        grad.insert(*key, gchunk);
    }
    Ok(grad)
}

fn eval_with_replaced(
    q: &Query,
    inputs: &[&Relation],
    slot: usize,
    replacement: &Relation,
    backend: &dyn KernelBackend,
) -> Result<f32> {
    let mut ins: Vec<&Relation> = inputs.to_vec();
    ins[slot] = replacement;
    eval_loss(q, &ins, backend)
}

/// Assert an analytic gradient matches finite differences within `tol`
/// (relative to magnitude). Keys absent from the analytic gradient are
/// required to have ≈0 numeric gradient.
pub fn assert_grad_close(
    analytic: &Relation,
    numeric: &Relation,
    tol: f32,
) {
    for (k, nv) in numeric.iter() {
        match analytic.get(k) {
            Some(av) => {
                assert!(
                    av.approx_eq(nv, tol),
                    "gradient mismatch at {k}: analytic {av:?} vs numeric {nv:?}"
                );
            }
            None => {
                assert!(
                    nv.data().iter().all(|x| x.abs() < tol),
                    "key {k} missing from analytic gradient but numeric is {nv:?}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::grad;
    use crate::kernels::{AggKernel, BinaryKernel, NativeBackend, UnaryKernel};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};
    use crate::ra::Key;
    use crate::util::Prng;
    use std::sync::Arc;

    #[test]
    fn finite_diff_confirms_eager_grad_on_mlp_like_query() {
        // loss = Σ relu(x·W)² over 2x2 blocks — exercises join(matmul),
        // select(relu/square) and agg in one chain.
        let mut rng = Prng::new(21);
        let x = Relation::from_pairs(vec![
            (Key::k2(0, 0), Chunk::random(2, 2, &mut rng, 1.0)),
            (Key::k2(1, 0), Chunk::random(2, 2, &mut rng, 1.0)),
        ]);
        let w = Relation::from_pairs(vec![(Key::k2(0, 0), Chunk::random(2, 2, &mut rng, 1.0))]);

        let mut qb = QueryBuilder::new();
        let ws = qb.scan(0, "W");
        let j = qb.join_const(
            JoinPred::on(vec![(0, 1)]), // W(k,h) joins X(i,k): L[0]=R[1]
            KeyProj2(vec![Sel2::R(0), Sel2::L(1)]),
            BinaryKernel::MatMulTN, // wait: X·W = (XᵀW?)  — use explicit orientation below
            ws,
            Arc::new(x.clone()),
            "X",
        );
        // Note: join kernel gets (W_chunk, X_chunk) = (L, R); X·W per block
        // is MatMul(X, W) = MatMulTN? Keep orientation simple: use
        // MatMulTN(W, X) = Wᵀ·X which is (X'·W)' — fine for a smoke loss.
        let r = qb.map(UnaryKernel::Relu, 2, j);
        let sq = qb.map(UnaryKernel::Square, 2, r);
        let sums = qb.map(UnaryKernel::SumAll, 2, sq);
        let out = qb.agg(KeyProj::to_empty(), AggKernel::Sum, sums);
        let q = qb.finish(out);

        let (_, grads) = grad(&q, &[&w], &NativeBackend).unwrap();
        let numeric = finite_diff_grad(&q, &[&w], 0, 1e-2, &NativeBackend).unwrap();
        assert_grad_close(grads.slot(0), &numeric, 5e-2);
    }
}
