//! Query planning.
//!
//! Logical planning (operator DAG construction) lives in `ra::expr`; the
//! cost-based physical decisions — broadcast vs co-partition joins,
//! two-phase aggregation, partitioning invariant propagation — live in
//! `dist::exec::plan_join` where they are applied per stage. This module
//! re-exports the stats/cardinality analyses used by both the optimizer
//! and the autodiff rewrites.

pub use crate::autodiff::optimize::{join_cardinality, JoinCard};
