//! Query planning.
//!
//! Logical planning (operator DAG construction) lives in `ra::expr`; the
//! cost-based physical decisions — co-partitioned vs broadcast vs
//! reshuffled joins ([`crate::dist::exec::plan_join`]), two-phase
//! aggregation, and partitioning-invariant propagation — live in
//! `dist::exec`, where they are applied per stage against the
//! [`crate::dist::NetModel`] prices. This module re-exports the
//! cardinality analyses shared by that planner and the autodiff
//! rewrites: `plan_join` biases its broadcast choice by
//! [`join_cardinality`], the same classification that drives the
//! backward-query Σ-elimination.
//!
//! The one logical-plan rewrite that lives here is [`factorize`]: the
//! factorized-evaluation pass that pushes partial Σ below ⋈ and emits
//! the partition hints the distributed executor uses to elide
//! shuffles.

pub mod factorize;

pub use crate::autodiff::optimize::{join_cardinality, JoinCard};
pub use factorize::{factorize_query, factorize_query_gated, FactorizedQuery, RewriteInfo};
