//! Query planning.
//!
//! Logical planning (operator DAG construction) lives in `ra::expr`; the
//! cost-based physical decisions — co-partitioned vs broadcast vs
//! reshuffled joins ([`crate::dist::exec::plan_join`]), two-phase
//! aggregation, and partitioning-invariant propagation — live in
//! `dist::exec`, where they are applied per stage against the
//! [`crate::dist::NetModel`] prices. This module re-exports the
//! cardinality analyses shared by that planner and the autodiff
//! rewrites: `plan_join` biases its broadcast choice by
//! [`join_cardinality`], the same classification that drives the
//! backward-query Σ-elimination.
//!
//! The logical-plan passes that live here are [`factorize`] — the
//! factorized-evaluation rewrite that pushes partial Σ below ⋈ and emits
//! the partition hints the distributed executor uses to elide shuffles —
//! and [`delta`], the legality gate deciding which query shapes may be
//! maintained incrementally under catalog inserts/deletes instead of
//! recomputed from scratch.

pub mod delta;
pub mod factorize;

pub use crate::autodiff::optimize::{join_cardinality, JoinCard};
pub use delta::delta_gate;
pub use factorize::{factorize_query, factorize_query_gated, FactorizedQuery, RewriteInfo};
