//! Plan-level legality gate for incremental (delta) maintenance.
//!
//! The executor's delta machinery (`dist::delta`) maintains a previously
//! executed tape under catalog updates by reusing clean subtrees,
//! appending insert-only suffixes through σ/⋈/Σ, and recomputing
//! everything else from the merged heads. All three mechanisms are
//! bitwise-safe for *any* operator — the gate below is the policy layer
//! on top: it decides which query shapes are allowed to be maintained
//! incrementally at all, mirroring the classical delta-rule preconditions
//! (F-IVM, Kara et al.):
//!
//! - `ΔQ = ΔR⋈S ∪ R⋈ΔS ∪ ΔR⋈ΔS` needs a pure equi-join to route deltas;
//!   cross products and literal-pinned predicates on the delta path are
//!   refused.
//! - Σ merges signed partials into the cached aggregate, which is only
//!   meaningful for `Sum`; `Max` cannot retract a deleted maximum and is
//!   refused.
//!
//! A refusal makes the whole frame fall back to full recompute from the
//! merged tables (bitwise-equal by construction), charges one
//! `ExecStats::delta_fallbacks`, and renders as `delta: refused(...)` in
//! `Frame::explain`.
//!
//! Only *touched* nodes are checked: a node is touched when a changed
//! input slot reaches it. An untouched `Max`-Σ subtree is served from the
//! previous tape verbatim (kernel-agnostic clean reuse), so it does not
//! force a fallback — e.g. the GCN loss query's literal-pinned weight
//! joins (`Node ⋈ W1`) refuse only when `Node` itself changed, not when
//! the update stream targets the label table.

use crate::kernels::AggKernel;
use crate::ra::expr::{Op, Query};

/// Decide whether `q` may be maintained incrementally given which input
/// slots changed since the tape being maintained was produced.
///
/// `changed` is indexed by scan slot; slots beyond its length are treated
/// as unchanged. `Ok(())` admits the delta path; `Err(reason)` is the
/// human-readable refusal rendered by `explain` as `delta:
/// refused(reason)`.
pub fn delta_gate(q: &Query, changed: &[bool]) -> Result<(), String> {
    // Forward pass: which nodes a changed slot reaches.
    let mut touched = vec![false; q.nodes.len()];
    for (id, node) in q.nodes.iter().enumerate() {
        touched[id] = match &node.op {
            Op::Scan { slot, .. } => changed.get(*slot).copied().unwrap_or(false),
            Op::Const { .. } => false,
            _ => node.children.iter().any(|&c| touched[c]),
        };
        if !touched[id] {
            continue;
        }
        match &node.op {
            Op::Agg { agg, .. } if *agg != AggKernel::Sum => {
                return Err(format!(
                    "Σ v{id} uses {agg:?} — only Sum merges signed delta partials"
                ));
            }
            Op::Join { pred, .. } if node.children.iter().any(|&c| touched[c]) => {
                if pred.eqs.is_empty() {
                    return Err(format!(
                        "⋈ v{id} is a cross product — no equi-key to route deltas by"
                    ));
                }
                if !pred.l_lits.is_empty() || !pred.r_lits.is_empty() {
                    return Err(format!(
                        "⋈ v{id} has a non-equi (literal-pinned) predicate on the delta path"
                    ));
                }
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{AggKernel, BinaryKernel};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel2};

    fn sum_join(pred: JoinPred, agg: AggKernel) -> Query {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            pred,
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), agg, j);
        qb.finish(a)
    }

    #[test]
    fn equi_sum_passes_and_refusals_are_reasoned() {
        let q = sum_join(JoinPred::on(vec![(0, 0)]), AggKernel::Sum);
        assert!(delta_gate(&q, &[true, false]).is_ok());
        assert!(delta_gate(&q, &[true, true]).is_ok());

        let q = sum_join(JoinPred::on(vec![(0, 0)]), AggKernel::Max);
        let err = delta_gate(&q, &[true, false]).unwrap_err();
        assert!(err.contains("Max"), "unexpected reason: {err}");

        let mut lit = JoinPred::on(vec![(0, 0)]);
        lit.l_lits.push((1, 3));
        let q = sum_join(lit, AggKernel::Sum);
        let err = delta_gate(&q, &[false, true]).unwrap_err();
        assert!(err.contains("non-equi"), "unexpected reason: {err}");

        let q = sum_join(JoinPred::cross(), AggKernel::Sum);
        assert!(delta_gate(&q, &[true, false]).is_err());
    }

    #[test]
    fn untouched_subtrees_do_not_refuse() {
        // Max-Σ over R, summed with an equi-join branch over S, T: updates
        // to S/T must pass the gate because the Max subtree is untouched.
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let t = qb.scan(2, "T");
        let m = qb.agg(KeyProj::take(&[0]), AggKernel::Max, r);
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0)]),
            BinaryKernel::Mul,
            s,
            t,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        let out = qb.add(m, a);
        let q = qb.finish(out);
        assert!(delta_gate(&q, &[false, true, true]).is_ok());
        assert!(delta_gate(&q, &[true, false, false]).is_err());
    }
}
