//! Factorized evaluation: push partial Σ below ⋈.
//!
//! Every benchmarked workload aggregates directly over a join output —
//! the `Σ(grp, ⊕, ⋈(pred, proj, ⊗, ·, ·))` shape — and materializes the
//! full `|R ⋈ S|` intermediate before summing. When `⊗` is linear in an
//! operand and the group keys only look at the components the join
//! predicate and grouping actually need, the sum distributes over the
//! join: tuples on that side that agree on the *kept* components can be
//! pre-summed before the join, shrinking both the shuffled bytes and the
//! build/probe sets to `|R| + |S|`-shaped work (the factorized-learning
//! collapse of Schleich/Olteanu, PAPERS.md).
//!
//! [`factorize_query`] rewrites each legal `Σ-over-⋈` pair into
//!
//! ```text
//! Σ_G ( ⋈(pred, proj, ⊗, L, R) )
//!   ⇒  Σ_G' ( ⋈(pred', concat, ⊗, Σ_keepL(L), Σ_keepR(R)) )
//! ```
//!
//! where `keepX` is the set of components of side X referenced by the
//! composed group key `G = grp ∘ proj` or by the join predicate, and the
//! partial Σ on a side is emitted only when it actually drops components
//! (`keep ⊊ key`) *and* `⊗` is linear in that operand
//! ([`BinaryKernel::linear_in`]). The rewritten join projects the full
//! concatenation of both (reduced) keys — injective over join pairs, so
//! the join output stays duplicate-free — and the combining Σ above
//! regroups by `G` re-expressed against the concatenated key.
//!
//! ## Legality rules (all must hold, else the pair is left untouched)
//!
//! - the aggregation kernel is `Sum` (`Max` does not distribute over a
//!   partial pre-merge of *values*, only of identical keys — refused);
//! - the Σ's child is the ⋈ itself (an `AddQ`/`σ` in between blocks the
//!   push) and the Σ is the join's *only* consumer;
//! - the join predicate is a pure equi-join (no literal constraints —
//!   those encode the paper's `⋈const` parameter joins, whose pinned
//!   component a partial Σ would have to carry anyway);
//! - every component of `G = grp ∘ proj` selects a side component (no
//!   literals), so the combining Σ can re-derive it from the
//!   concatenated key;
//! - at least one side collapses: `keep ⊊ components` with `⊗` linear in
//!   that operand.
//!
//! ## Partition-aware gating and shuffle elision
//!
//! [`factorize_query_gated`] additionally consults the live
//! [`PartitionedRelation`] layouts ("interesting orders"): a side only
//! collapses when its scan is already hash-partitioned on a subset of
//! the kept components (the partial Σ is then shuffle-free) or when the
//! measured distinct-subkey ratio shows real collapse
//! (< [`COLLAPSE_RATIO`]). The emitted [`FactorizedQuery::agg_exchange`]
//! hints let the executor hash a partial Σ's two-phase exchange on the
//! *join-predicate* components instead of the full group key, so one
//! shuffle serves both the Σ and the join co-partitioning; the
//! executor-side reshuffle memo (`dist::exec`) then elides any repeat
//! movement of the same node on the same key. Both halves are A/B
//! switchable per session (`ClusterConfig::{factorize_agg,
//! elide_shuffles}`).

use crate::autodiff::graph::node_arities;
use crate::autodiff::optimize::compose_grp_proj;
use crate::dist::{PartitionedRelation, Partitioning};
use crate::kernels::AggKernel;
use crate::ra::expr::{Node, NodeId, Op, Query};
use crate::ra::funcs::{JoinPred, KeyProj, KeyProj2, Sel, Sel2};
use crate::util::{FxHashMap, FxHashSet};

/// A side only collapses (under the data-aware gate) when partial Σ
/// shrinks it to under this fraction of its tuples.
pub const COLLAPSE_RATIO: f64 = 0.75;

/// One applied Σ-below-⋈ rewrite (for `explain`/`trace` rendering).
#[derive(Clone, Debug)]
pub struct RewriteInfo {
    /// The original Σ node (replaced by the combining Σ).
    pub agg: NodeId,
    /// The original ⋈ node underneath it.
    pub join: NodeId,
    pub pushed_left: bool,
    pub pushed_right: bool,
    /// Components kept per side (full identity when the side didn't
    /// collapse).
    pub keep_l: Vec<usize>,
    pub keep_r: Vec<usize>,
}

impl RewriteInfo {
    /// One-line human rendering for `Frame::explain`.
    pub fn render(&self) -> String {
        let side = |pushed: bool, keep: &[usize]| {
            if pushed {
                format!("Σ{keep:?}")
            } else {
                "·".to_string()
            }
        };
        format!(
            "Σ v{} over ⋈ v{} → ⟨{} ⋈ {}⟩ + combining Σ",
            self.agg,
            self.join,
            side(self.pushed_left, &self.keep_l),
            side(self.pushed_right, &self.keep_r),
        )
    }
}

/// Result of the rewrite pass: the factorized query plus the metadata
/// the session layer needs to execute and render it.
pub struct FactorizedQuery {
    pub query: Query,
    /// Original node id → id in `query` (partial Σs have no preimage).
    pub node_map: Vec<NodeId>,
    pub rewrites: Vec<RewriteInfo>,
    /// `(partial-Σ node in query, exchange components)`: the two-phase
    /// exchange of this Σ may hash on these group-key components (the
    /// join-predicate positions) instead of the full group key, landing
    /// its output co-partitioned for the join above — one shuffle serves
    /// both. Hashing on a subset of the group key still co-locates every
    /// group, and the per-key merge order (worker index order) is
    /// unchanged, so results are bitwise identical tuple-for-tuple.
    pub agg_exchange: Vec<(NodeId, Vec<usize>)>,
}

struct Candidate {
    agg: NodeId,
    join: NodeId,
    collapse_l: bool,
    collapse_r: bool,
    /// Effective kept components per side (identity when not collapsed).
    keep_l: Vec<usize>,
    keep_r: Vec<usize>,
    /// `G = grp ∘ proj` — the group key against the original join inputs.
    grp2: KeyProj2,
}

fn position(keep: &[usize], comp: usize) -> usize {
    keep.iter()
        .position(|&k| k == comp)
        .expect("kept component missing")
}

fn find_candidates(q: &Query, in_arities: &[usize]) -> Vec<Candidate> {
    let arities = node_arities(q, in_arities);
    let consumers = q.consumers();
    let mut out = Vec::new();
    for (a, node) in q.nodes.iter().enumerate() {
        let Op::Agg { grp, agg } = &node.op else {
            continue;
        };
        if *agg != AggKernel::Sum {
            continue;
        }
        let j = node.children[0];
        let Op::Join { pred, proj, kernel } = &q.nodes[j].op else {
            continue;
        };
        if consumers[j].len() != 1 || j == q.output {
            continue;
        }
        if !pred.l_lits.is_empty() || !pred.r_lits.is_empty() {
            continue;
        }
        let grp2 = compose_grp_proj(grp, proj);
        if grp2.0.iter().any(|s| matches!(s, Sel2::Lit(_))) {
            continue;
        }
        let la = arities[q.nodes[j].children[0]];
        let ra = arities[q.nodes[j].children[1]];
        let keep = |side_comps: Vec<usize>, pred_comps: Vec<usize>| {
            let mut k: Vec<usize> = side_comps.into_iter().chain(pred_comps).collect();
            k.sort_unstable();
            k.dedup();
            k
        };
        let keep_l = keep(
            grp2.0
                .iter()
                .filter_map(|s| match s {
                    Sel2::L(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            pred.left_comps(),
        );
        let keep_r = keep(
            grp2.0
                .iter()
                .filter_map(|s| match s {
                    Sel2::R(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            pred.right_comps(),
        );
        // Malformed-query guard (component out of range): refuse.
        if keep_l.iter().any(|&i| i >= la) || keep_r.iter().any(|&i| i >= ra) {
            continue;
        }
        let collapse_l = keep_l.len() < la && kernel.linear_in(true);
        let collapse_r = keep_r.len() < ra && kernel.linear_in(false);
        if !collapse_l && !collapse_r {
            continue;
        }
        out.push(Candidate {
            agg: a,
            join: j,
            collapse_l,
            collapse_r,
            keep_l: if collapse_l {
                keep_l
            } else {
                (0..la).collect()
            },
            keep_r: if collapse_r {
                keep_r
            } else {
                (0..ra).collect()
            },
            grp2,
        });
    }
    out
}

/// Data-aware gate: a collapsing side must be a scan whose live layout
/// promises the partial Σ is either shuffle-free (already hash-placed on
/// kept components) or genuinely collapsing (distinct-subkey ratio under
/// [`COLLAPSE_RATIO`]).
fn data_gate(q: &Query, c: &Candidate, inputs: &[PartitionedRelation]) -> bool {
    let side_ok = |child: NodeId, keep: &[usize]| {
        let Op::Scan { slot, .. } = &q.nodes[child].op else {
            return false;
        };
        let Some(rel) = inputs.get(*slot) else {
            return false;
        };
        // `hash_comps` covers `SkewHash` too: the hot-key annotation must
        // not change which plan factorizes, or a skewed session would
        // diverge from its oblivious twin before execution even starts.
        if let Some(comps) = rel.part.hash_comps() {
            if !comps.is_empty() && comps.iter().all(|c| keep.contains(c)) {
                return true;
            }
        }
        let proj = KeyProj::take(keep);
        let mut distinct: FxHashSet<crate::ra::Key> = FxHashSet::default();
        let mut total = 0usize;
        let shards: &[_] = match rel.part {
            Partitioning::Replicated => &rel.shards[..1.min(rel.shards.len())],
            _ => &rel.shards,
        };
        for shard in shards {
            total += shard.len();
            for (k, _) in shard.iter() {
                distinct.insert(proj.apply(k));
            }
        }
        total == 0 || (distinct.len() as f64) < COLLAPSE_RATIO * total as f64
    };
    let join = &q.nodes[c.join];
    (!c.collapse_l || side_ok(join.children[0], &c.keep_l))
        && (!c.collapse_r || side_ok(join.children[1], &c.keep_r))
}

fn build(q: &Query, cands: Vec<Candidate>) -> Option<FactorizedQuery> {
    if cands.is_empty() {
        return None;
    }
    let by_join: FxHashMap<NodeId, usize> =
        cands.iter().enumerate().map(|(i, c)| (c.join, i)).collect();
    let by_agg: FxHashMap<NodeId, usize> =
        cands.iter().enumerate().map(|(i, c)| (c.agg, i)).collect();
    let mut nodes: Vec<Node> = Vec::with_capacity(q.nodes.len() + 2 * cands.len());
    let mut node_map = vec![usize::MAX; q.nodes.len()];
    let mut agg_exchange = Vec::new();
    for (i, node) in q.nodes.iter().enumerate() {
        if let Some(&ci) = by_join.get(&i) {
            let c = &cands[ci];
            let Op::Join { pred, kernel, .. } = &node.op else {
                unreachable!("candidate join is a join");
            };
            let mut l_in = node_map[node.children[0]];
            let mut r_in = node_map[node.children[1]];
            if c.collapse_l {
                nodes.push(Node {
                    op: Op::Agg {
                        grp: KeyProj::take(&c.keep_l),
                        agg: AggKernel::Sum,
                    },
                    children: vec![l_in],
                });
                l_in = nodes.len() - 1;
            }
            if c.collapse_r {
                nodes.push(Node {
                    op: Op::Agg {
                        grp: KeyProj::take(&c.keep_r),
                        agg: AggKernel::Sum,
                    },
                    children: vec![r_in],
                });
                r_in = nodes.len() - 1;
            }
            let eqs2: Vec<(usize, usize)> = pred
                .eqs
                .iter()
                .map(|&(l, r)| (position(&c.keep_l, l), position(&c.keep_r, r)))
                .collect();
            // Exchange hints: a partial Σ may hash on the join positions
            // (subset of its group key) so its shuffle doubles as the
            // join's co-partitioning. Only when the positions are
            // duplicate-free and actually differ from the default.
            let hint = |comps: Vec<usize>, out_arity: usize, agg_node: NodeId| {
                let distinct = comps.iter().collect::<FxHashSet<_>>().len() == comps.len();
                let is_default = comps.iter().copied().eq(0..out_arity);
                if !comps.is_empty() && distinct && !is_default {
                    Some((agg_node, comps))
                } else {
                    None
                }
            };
            if c.collapse_l {
                agg_exchange.extend(hint(
                    eqs2.iter().map(|&(l, _)| l).collect(),
                    c.keep_l.len(),
                    l_in,
                ));
            }
            if c.collapse_r {
                agg_exchange.extend(hint(
                    eqs2.iter().map(|&(_, r)| r).collect(),
                    c.keep_r.len(),
                    r_in,
                ));
            }
            let proj2 = KeyProj2(
                (0..c.keep_l.len())
                    .map(Sel2::L)
                    .chain((0..c.keep_r.len()).map(Sel2::R))
                    .collect(),
            );
            nodes.push(Node {
                op: Op::Join {
                    pred: JoinPred::on(eqs2),
                    proj: proj2,
                    kernel: *kernel,
                },
                children: vec![l_in, r_in],
            });
            node_map[i] = nodes.len() - 1;
        } else if let Some(&ci) = by_agg.get(&i) {
            let c = &cands[ci];
            let grp2 = KeyProj(
                c.grp2
                    .0
                    .iter()
                    .map(|s| match *s {
                        Sel2::L(l) => Sel::C(position(&c.keep_l, l)),
                        Sel2::R(r) => Sel::C(c.keep_l.len() + position(&c.keep_r, r)),
                        Sel2::Lit(_) => unreachable!("literal group keys are refused"),
                    })
                    .collect(),
            );
            nodes.push(Node {
                op: Op::Agg {
                    grp: grp2,
                    agg: AggKernel::Sum,
                },
                children: vec![node_map[c.join]],
            });
            node_map[i] = nodes.len() - 1;
        } else {
            nodes.push(Node {
                op: node.op.clone(),
                children: node.children.iter().map(|&ch| node_map[ch]).collect(),
            });
            node_map[i] = nodes.len() - 1;
        }
    }
    let rewrites = cands
        .into_iter()
        .map(|c| RewriteInfo {
            agg: c.agg,
            join: c.join,
            pushed_left: c.collapse_l,
            pushed_right: c.collapse_r,
            keep_l: c.keep_l,
            keep_r: c.keep_r,
        })
        .collect();
    Some(FactorizedQuery {
        query: Query {
            nodes,
            output: node_map[q.output],
            n_slots: q.n_slots,
        },
        node_map,
        rewrites,
        agg_exchange,
    })
}

/// Structural rewrite: push partial Σ below every legal ⋈. Returns
/// `None` when no Σ-over-⋈ pair is legal (the plan is left untouched).
pub fn factorize_query(q: &Query, in_arities: &[usize]) -> Option<FactorizedQuery> {
    build(q, find_candidates(q, in_arities))
}

/// As [`factorize_query`], but additionally gated on the live input
/// layouts: a candidate is only rewritten when every collapsing side is
/// a scan that is either already hash-partitioned on kept components or
/// measurably collapsing (see [`COLLAPSE_RATIO`]). This is the variant
/// the session/trainer paths use.
pub fn factorize_query_gated(
    q: &Query,
    in_arities: &[usize],
    inputs: &[PartitionedRelation],
) -> Option<FactorizedQuery> {
    let cands = find_candidates(q, in_arities)
        .into_iter()
        .filter(|c| data_gate(q, c, inputs))
        .collect();
    build(q, cands)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::BinaryKernel;
    use crate::ra::expr::{matmul_query, QueryBuilder};
    use crate::ra::{Chunk, Key, Relation};

    /// `Σ_{a} ( R(a,b) ⋈_{a=a} S(a,c) )` with an elementwise product:
    /// both sides keep only component 0 — the textbook factorizable
    /// shape.
    fn sumjoin_query() -> Query {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        qb.finish(a)
    }

    #[test]
    fn sumjoin_pushes_both_sides() {
        let q = sumjoin_query();
        let f = factorize_query(&q, &[2, 2]).expect("rewrite fires");
        let counts = f.query.op_counts();
        assert_eq!(counts["Σ"], 3, "two partial + one combining Σ");
        assert_eq!(counts["⋈"], 1);
        assert_eq!(f.rewrites.len(), 1);
        assert!(f.rewrites[0].pushed_left && f.rewrites[0].pushed_right);
        assert_eq!(f.rewrites[0].keep_l, vec![0]);
        assert_eq!(f.rewrites[0].keep_r, vec![0]);
        // Output maps to the combining Σ; join arity shrank to ⟨L0,R0⟩.
        assert_eq!(f.node_map[q.output], f.query.output);
        let Op::Join { pred, proj, .. } = &f.query.nodes[f.node_map[2]].op else {
            panic!("mapped node is the join")
        };
        assert_eq!(pred.eqs, vec![(0, 0)]);
        assert_eq!(proj.out_arity(), 2);
        // keep == join comps == [0] on both sides: the exchange hint is
        // the default full key, so no override is emitted.
        assert!(f.agg_exchange.is_empty());
    }

    #[test]
    fn exchange_hint_emitted_when_group_widens_the_key() {
        // Σ over ⟨L0,R1⟩ with join on L1=R0: keeps are {0,1} on both
        // sides, join positions are a strict subset → hints fire.
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(1, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::identity(2), AggKernel::Sum, j);
        let q = qb.finish(a);
        let f = factorize_query(&q, &[3, 3]).expect("rewrite fires");
        assert_eq!(f.agg_exchange.len(), 2);
        for (_, comps) in &f.agg_exchange {
            assert_eq!(comps.len(), 1, "hash on the single join position");
        }
    }

    #[test]
    fn matmul_keep_is_full_so_rewrite_refuses() {
        // Σ_{0,2}(A(i,k) ⋈ B(k,j)): G ∪ pred covers both components of
        // both sides — nothing collapses.
        let q = matmul_query();
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    #[test]
    fn refuses_when_join_has_another_consumer_or_is_output() {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        let both = qb.add(a, j); // second consumer of the join
        let q = qb.finish(both);
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    #[test]
    fn refuses_literal_group_keys_from_projection() {
        // Σ group key produced by the join projection as a literal —
        // satellite: "group keys produced by the join projection".
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::Lit(7), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0, 1]), AggKernel::Sum, j);
        let q = qb.finish(a);
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    #[test]
    fn refuses_addq_between_agg_and_join() {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j1 = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let j2 = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            s,
            r,
        );
        let add = qb.add(j1, j2);
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, add);
        let q = qb.finish(a);
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    #[test]
    fn refuses_non_decomposable_agg_kernels() {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Max, j);
        let q = qb.finish(a);
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    #[test]
    fn refuses_nonlinear_kernels() {
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Add,
            r,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        let q = qb.finish(a);
        assert!(factorize_query(&q, &[2, 2]).is_none());
    }

    fn two_comp_rel(n: i64, repeat: i64) -> Relation {
        // Keys ⟨a, b⟩ with a = i / repeat — `repeat` tuples per group.
        Relation::from_pairs(
            (0..n)
                .map(|i| (Key::k2(i / repeat, i), Chunk::scalar(i as f32)))
                .collect(),
        )
    }

    #[test]
    fn data_gate_accepts_hash_on_kept_and_rejects_high_cardinality() {
        let q = sumjoin_query();
        // Hash-partitioned on the kept component: accepted regardless of
        // cardinality.
        let hashed = PartitionedRelation::hash_partition(&two_comp_rel(8, 1), &[0], 2);
        let gated = factorize_query_gated(&q, &[2, 2], &[hashed.clone(), hashed]);
        assert!(gated.is_some(), "hash-on-kept side passes the gate");
        // Arbitrary placement + every tuple its own group: no collapse,
        // the gate refuses.
        let unique = PartitionedRelation::hash_partition(&two_comp_rel(8, 1), &[1], 2);
        let gated = factorize_query_gated(&q, &[2, 2], &[unique.clone(), unique]);
        assert!(gated.is_none(), "unique-key side fails the ratio gate");
        // Badly partitioned but genuinely collapsing (4 tuples/group):
        // the ratio gate accepts.
        let fat = PartitionedRelation::hash_partition(&two_comp_rel(16, 4), &[1], 2);
        let gated = factorize_query_gated(&q, &[2, 2], &[fat.clone(), fat]);
        assert!(gated.is_some(), "collapsing side passes the ratio gate");
    }

    #[test]
    fn untouched_nodes_are_remapped_identically() {
        // A query with a non-candidate prefix keeps its structure and
        // the node_map stays consistent.
        let mut qb = QueryBuilder::new();
        let r = qb.scan(0, "R");
        let s = qb.scan(1, "S");
        let rr = qb.map(crate::kernels::UnaryKernel::Relu, 2, r);
        let j = qb.join(
            JoinPred::on(vec![(0, 0)]),
            KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
            BinaryKernel::Mul,
            rr,
            s,
        );
        let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
        let q = qb.finish(a);
        let f = factorize_query(&q, &[2, 2]).expect("rewrite fires");
        for (orig, &new) in f.node_map.iter().enumerate() {
            assert!(new < f.query.nodes.len());
            assert_eq!(
                q.nodes[orig].op.kind() == "σ",
                f.query.nodes[new].op.kind() == "σ",
                "non-candidate ops keep their kind"
            );
        }
        // Children always precede parents in the rewritten DAG.
        for (i, n) in f.query.nodes.iter().enumerate() {
            for &c in &n.children {
                assert!(c < i, "node {i} has non-topological child {c}");
            }
        }
    }
}
