//! Shared drivers for the paper-table benchmark binaries
//! (`rust/benches/*`, built with `harness = false`).

use crate::baselines::BaselineResult;
use crate::data::GraphDataset;
use crate::dist::{ClusterConfig, DistError, MemPolicy, PartitionedRelation};
use crate::kernels::KernelBackend;
use crate::ml::gcn::{self, GcnConfig};
use crate::ml::DistTrainer;
use crate::ra::Relation;
use crate::util::Prng;

/// Per-epoch time of RA-GCN on the virtual cluster.
/// `minibatch = Some(b)`: one measured batch step × (labeled / b) steps;
/// `None`: full-graph training (one step per epoch). The RA engine runs
/// with `MemPolicy::Spill` — it degrades instead of OOMing (the paper's
/// headline behaviour).
pub fn ra_gcn_epoch(
    g: &GraphDataset,
    workers: usize,
    budget: Option<u64>,
    minibatch: Option<usize>,
    backend: &dyn KernelBackend,
) -> Result<f64, DistError> {
    let cfg = GcnConfig {
        feat_dim: g.feat_dim,
        hidden: 64,
        n_labels: g.n_labels,
        dropout: Some(0.5),
        seed: 0xBE,
    };
    let mut rng = Prng::new(0xE90C);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);
    // Mini-batch: one measured representative step over the batch's
    // fanout-sampled 2-hop cone (the relational selection pushdown a DB
    // optimizer applies when the loss only touches the batch), scaled by
    // the number of batches per epoch. Full-graph: one step, everything.
    let (edges, feats, labels, steps): (Relation, Relation, Relation, usize) = match minibatch {
        Some(b) => {
            let yb = gcn::batch_labels(&g.labels, &g.labeled, b, &mut rng);
            let seeds: Vec<u32> = yb.iter().map(|(k, _)| k.get(0) as u32).collect();
            let csr = crate::baselines::gnn_common::build_csr(g);
            let (cone, sampled) = crate::baselines::gnn_common::sample_2hop_edges(
                &csr, &seeds, 10, 25, &mut rng,
            );
            let mut e = Relation::new();
            for &(dst, src) in &sampled {
                let k = crate::ra::Key::k2(dst as i64, src as i64);
                if !e.contains(&k) {
                    if let Some(w) = g.edges.get(&k) {
                        e.insert(k, w.clone());
                    }
                }
            }
            for &u in &cone {
                let k = crate::ra::Key::k2(u as i64, u as i64);
                if !e.contains(&k) {
                    if let Some(w) = g.edges.get(&k) {
                        e.insert(k, w.clone());
                    }
                }
            }
            let mut f = Relation::new();
            for &u in &cone {
                let k = crate::ra::Key::k1(u as i64);
                if let Some(v) = g.feats.get(&k) {
                    f.insert(k, v.clone());
                }
            }
            (e, f, yb, g.labeled.len().div_ceil(b).max(1))
        }
        None => (g.edges.clone(), g.feats.clone(), g.labels.clone(), 1),
    };
    let q = gcn::loss_query(&cfg, labels.len());
    let trainer = DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2])
        .map_err(DistError::Other)?;
    let mut ccfg = ClusterConfig::new(workers).with_policy(MemPolicy::Spill);
    if let Some(b) = budget {
        ccfg = ccfg.with_budget(b);
    }
    let inputs = vec![
        PartitionedRelation::replicate(&w1, workers),
        PartitionedRelation::replicate(&w2, workers),
        PartitionedRelation::hash_partition(&edges, &[0], workers),
        PartitionedRelation::hash_full(&feats, workers),
        PartitionedRelation::hash_full(&labels, workers),
    ];
    let res = trainer.step(&inputs, &ccfg, backend)?;
    Ok(res.stats.virtual_time_s * steps as f64)
}

/// Format a `Result<f64, DistError>` / `BaselineResult` into a table cell.
pub fn cell(r: &Result<f64, DistError>) -> String {
    match r {
        Ok(t) => format!("{t:.3}s"),
        Err(DistError::Oom { .. }) => "OOM".to_string(),
        Err(e) => format!("ERR({e})"),
    }
}

pub fn bcell(r: &BaselineResult) -> String {
    r.display()
}

/// Print a markdown-ish row.
pub fn print_row(name: &str, cells: &[String]) {
    let body = cells
        .iter()
        .map(|c| format!("{c:>12}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("{name:<14} {body}");
}

pub fn print_header(title: &str, workers: &[usize]) {
    println!("\n=== {title} ===");
    let cols = workers
        .iter()
        .map(|w| format!("{w:>12}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("{:<14} {cols}", "system\\W");
}
