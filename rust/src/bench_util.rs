//! Shared drivers for the paper-table benchmark binaries
//! (`rust/benches/*`, built with `harness = false`), and the
//! machine-readable perf-trajectory output (`BENCH_dist.json`).

use crate::baselines::BaselineResult;
use crate::data::GraphDataset;
use crate::dist::{
    ClusterConfig, DistError, ExecStats, FaultKind, FaultPlan, InjectionPoint, MemPolicy,
    PartitionedRelation,
};
use crate::kernels::KernelBackend;
use crate::ml::gcn::{self, GcnConfig};
use crate::ml::{nnmf, DistTrainer, SlotLayout};
use crate::ra::Relation;
use crate::session::{ModelSpec, Session, SessionError};
use crate::util::Prng;
use std::sync::Arc;

/// Map a session error onto the bench cell vocabulary (`DistError` —
/// OOM cells render as OOM, everything else as ERR).
fn to_dist_err(e: SessionError) -> DistError {
    match e {
        SessionError::Exec(d) => d,
        other => DistError::Other(anyhow::anyhow!("{other}")),
    }
}

/// Per-epoch time of RA-GCN on the virtual cluster.
/// `minibatch = Some(b)`: one measured batch step × (labeled / b) steps;
/// `None`: full-graph training (one step per epoch). The RA engine runs
/// with `MemPolicy::Spill` — it degrades instead of OOMing (the paper's
/// headline behaviour).
pub fn ra_gcn_epoch(
    g: &GraphDataset,
    workers: usize,
    budget: Option<u64>,
    minibatch: Option<usize>,
    backend: &dyn KernelBackend,
) -> Result<f64, DistError> {
    let cfg = GcnConfig {
        feat_dim: g.feat_dim,
        hidden: 64,
        n_labels: g.n_labels,
        dropout: Some(0.5),
        seed: 0xBE,
    };
    let mut rng = Prng::new(0xE90C);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);
    // Mini-batch: one measured representative step over the batch's
    // fanout-sampled 2-hop cone (the relational selection pushdown a DB
    // optimizer applies when the loss only touches the batch), scaled by
    // the number of batches per epoch. Full-graph: one step, everything.
    let (edges, feats, labels, steps): (Relation, Relation, Relation, usize) = match minibatch {
        Some(b) => {
            let yb = gcn::batch_labels(&g.labels, &g.labeled, b, &mut rng);
            let seeds: Vec<u32> = yb.iter().map(|(k, _)| k.get(0) as u32).collect();
            let csr = crate::baselines::gnn_common::build_csr(g);
            let (cone, sampled) = crate::baselines::gnn_common::sample_2hop_edges(
                &csr, &seeds, 10, 25, &mut rng,
            );
            let mut e = Relation::new();
            for &(dst, src) in &sampled {
                let k = crate::ra::Key::k2(dst as i64, src as i64);
                if !e.contains(&k) {
                    if let Some(w) = g.edges.get(&k) {
                        e.insert(k, w.clone());
                    }
                }
            }
            for &u in &cone {
                let k = crate::ra::Key::k2(u as i64, u as i64);
                if !e.contains(&k) {
                    if let Some(w) = g.edges.get(&k) {
                        e.insert(k, w.clone());
                    }
                }
            }
            let mut f = Relation::new();
            for &u in &cone {
                let k = crate::ra::Key::k1(u as i64);
                if let Some(v) = g.feats.get(&k) {
                    f.insert(k, v.clone());
                }
            }
            (e, f, yb, g.labeled.len().div_ceil(b).max(1))
        }
        None => (g.edges.clone(), g.feats.clone(), g.labels.clone(), 1),
    };
    let q = gcn::loss_query(&cfg, labels.len());
    let trainer = DistTrainer::new(q, &[1, 1, 2, 1, 1], &[gcn::SLOT_W1, gcn::SLOT_W2])
        .map_err(DistError::Other)?;
    let mut ccfg = ClusterConfig::new(workers).with_policy(MemPolicy::Spill);
    if let Some(b) = budget {
        ccfg = ccfg.with_budget(b);
    }
    let inputs = vec![
        PartitionedRelation::replicate(&w1, workers),
        PartitionedRelation::replicate(&w2, workers),
        PartitionedRelation::hash_partition(&edges, &[0], workers),
        PartitionedRelation::hash_full(&feats, workers),
        PartitionedRelation::hash_full(&labels, workers),
    ];
    // Legacy one-shot step: the table benches sweep (workers × budget ×
    // backend) with per-call partitioned inputs, which the positional API
    // expresses directly. Migrating them to per-combination sessions is
    // tracked with the deprecated surface's removal.
    #[allow(deprecated)]
    let res = trainer.step(&inputs, &ccfg, backend)?;
    Ok(res.stats.virtual_time_s * steps as f64)
}

/// One (workers → clocks) measurement of a distributed workload.
#[derive(Clone, Copy, Debug)]
pub struct DistBenchPoint {
    pub workers: usize,
    /// Measured wall seconds per training step (warm partition cache)
    /// of the *materialized baseline*: the full pooled path — stage
    /// compute and shuffle/gather/Σ-merge sharded across the persistent
    /// worker pool — but with factorized evaluation (Σ pushdown +
    /// shuffle elision) off. The optimized columns below are measured
    /// against this row.
    pub wall_s: f64,
    /// The same step with `parallel_comm = false`: stage compute still
    /// pooled, but every exchange, gather and Σ merge serialized on the
    /// driver thread — the pre-pool executor. The gap to `wall_s`
    /// isolates the parallel-communication win.
    pub wall_s_driver_comm: f64,
    /// The same pooled step under a deliberately low per-worker budget,
    /// grace-spilling over-budget build sides to real temp files — the
    /// out-of-core column. The gap to `wall_s` is the measured price of
    /// running the step out-of-core on this host.
    pub wall_s_spill: f64,
    /// Measured spill temp-file bytes written per low-budget step
    /// (zero would mean the chosen budget failed to force spill).
    pub spill_bytes_written: u64,
    /// The pooled step with factorized evaluation on
    /// (`ClusterConfig::with_factorize(true)`, the session default):
    /// Σ-below-⋈ pushdown where legal plus partition-aware shuffle
    /// elision. `wall_s` itself is measured with both knobs *off* — the
    /// materialized baseline — so the gap is the factorization win.
    pub wall_s_factorized: f64,
    /// Modeled shuffle traffic per materialized step.
    pub bytes_shuffled: u64,
    /// Modeled shuffle traffic per factorized step (strictly lower than
    /// `bytes_shuffled` whenever a rewrite or elision fired).
    pub bytes_shuffled_factorized: u64,
    /// Shuffles the factorized step served from the elision memo.
    pub shuffles_elided: u64,
    /// The pooled step under the standard scripted fault plan
    /// ([`bench_fault_plan`]): one transient error and one injected
    /// worker panic per execution, each retried via lineage replay. The
    /// run is bitwise identical to `wall_s`'s (the smoke assertion pins
    /// loss bits), so the gap to `wall_s` is the measured price of the
    /// recovery replays.
    pub wall_s_faulty: f64,
    /// Modeled virtual-cluster seconds per step.
    pub virtual_time_s: f64,
    /// Real speedup on this host relative to the *baseline* row — the
    /// smallest worker count that produced a measurement (`workers = 1`
    /// unless that run errored, in which case the baseline row records
    /// `speedup = 1.0` at its own worker count).
    pub speedup: f64,
}

/// Per-step averages of one measured trainer configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepClocks {
    /// Measured wall seconds per step.
    pub wall_s: f64,
    /// Modeled virtual-cluster seconds per step.
    pub virtual_time_s: f64,
    /// Measured spill temp-file bytes written per step (nonzero only
    /// under a budget tight enough to force grace passes).
    pub spill_bytes_written: u64,
    /// Modeled shuffle traffic per step.
    pub bytes_shuffled: u64,
    /// Shuffles served from the elision memo per step (nonzero only
    /// with factorized evaluation on).
    pub shuffles_elided: u64,
}

/// The standard scripted fault plan the benches run their faulty column
/// under: one transient error and one injected worker panic per
/// execution (occurrence coordinates restart per forward/backward
/// evaluation), both on structurally guaranteed sites — every step of
/// every workload exercises the retry/lineage-replay path at least
/// twice.
pub fn bench_fault_plan() -> FaultPlan {
    FaultPlan::new()
        .once(InjectionPoint::JoinBuild, 0, 1, FaultKind::TransientError)
        .once(InjectionPoint::JoinProbe, 0, 2, FaultKind::PanicJob)
}

/// A faulted measurement: the per-step clocks plus what the injected
/// faults did, and the loss bit patterns the smoke assertion compares
/// against the fault-free run.
#[derive(Clone, Debug, Default)]
pub struct FaultedClocks {
    pub clocks: StepClocks,
    /// `loss.to_bits()` of every step, warm-up included — bitwise equal
    /// to the fault-free run's when recovery is sound.
    pub loss_bits: Vec<u32>,
    /// Total stage retries across all steps (forward and backward).
    pub stage_retries: u64,
    /// Total faults injected across all steps.
    pub faults_injected: u64,
}

/// Per-step clocks of the table2 GCN workload: a `Session` trainer run
/// for `steps` steps; step 0 (warm-up: allocator, caches) is excluded
/// from the averages. The session catalog holds the graph tables
/// partitioned once, so the measurement isolates stage execution, not
/// input scatter or backend minting. `parallel_comm = false` keeps the
/// communication steps on the driver thread (the A/B baseline);
/// `budget = Some(b)` bounds every worker at `b` bytes so over-budget
/// joins grace-spill through real temp files (the out-of-core column);
/// `factorize = false` turns factorized evaluation (Σ pushdown +
/// shuffle elision) off — the materialized A/B baseline.
#[allow(clippy::too_many_arguments)]
pub fn gcn_step_clocks(
    g: &GraphDataset,
    hidden: usize,
    workers: usize,
    steps: usize,
    parallel_comm: bool,
    budget: Option<u64>,
    factorize: bool,
    backend: &dyn KernelBackend,
) -> Result<StepClocks, DistError> {
    gcn_step_clocks_faulted(
        g,
        hidden,
        workers,
        steps,
        parallel_comm,
        budget,
        factorize,
        None,
        backend,
    )
    .map(|f| f.clocks)
}

/// [`gcn_step_clocks`] with an optional scripted [`FaultPlan`] — the
/// faulty bench column. Also returns every step's loss bits and the
/// fault/retry totals, so the smoke run can assert the faulted loop is
/// bitwise identical to the clean one.
#[allow(clippy::too_many_arguments)]
pub fn gcn_step_clocks_faulted(
    g: &GraphDataset,
    hidden: usize,
    workers: usize,
    steps: usize,
    parallel_comm: bool,
    budget: Option<u64>,
    factorize: bool,
    fault_plan: Option<FaultPlan>,
    backend: &dyn KernelBackend,
) -> Result<FaultedClocks, DistError> {
    let cfg = GcnConfig {
        feat_dim: g.feat_dim,
        hidden,
        n_labels: g.n_labels,
        dropout: None,
        seed: 0xBE,
    };
    let mut rng = Prng::new(0xE90C);
    let (w1, w2) = gcn::init_params(&cfg, &mut rng);
    let q = gcn::loss_query(&cfg, g.labels.len());
    let mut ccfg = ClusterConfig::new(workers)
        .with_policy(MemPolicy::Spill)
        .with_parallel_comm(parallel_comm)
        .with_factorize(factorize);
    if let Some(b) = budget {
        ccfg = ccfg.with_budget(b);
    }
    if let Some(plan) = fault_plan {
        ccfg = ccfg.with_fault_plan(plan);
    }
    // One owned backend instance for the session root (`for_worker` is
    // exactly the "runtime of one node" hook; the native backend is a
    // ZST, and benches never run the counting backend).
    let sess = Session::with_backend(ccfg, backend.for_worker());
    sess.register_with_layout("Edge", &["dst", "src"], &g.edges, &SlotLayout::HashOn(vec![0]))
        .map_err(to_dist_err)?;
    sess.register("Node", &["id"], &g.feats).map_err(to_dist_err)?;
    sess.register("Y", &["id"], &g.labels).map_err(to_dist_err)?;
    let mut trainer = sess
        .trainer(ModelSpec::new(q).param("W1", 1).param("W2", 1))
        .map_err(to_dist_err)?;
    let mut stats = ExecStats::default();
    let mut out = FaultedClocks::default();
    for step in 0..steps.max(2) {
        let res = trainer
            .step(&[("W1", &w1), ("W2", &w2)])
            .map_err(to_dist_err)?;
        out.loss_bits.push(res.loss.to_bits());
        out.stage_retries += res.stats.stage_retries;
        out.faults_injected += res.stats.faults_injected;
        if step > 0 {
            stats.merge(&res.stats);
        }
    }
    out.clocks = per_step(&stats, steps.max(2) - 1);
    Ok(out)
}

/// Average accumulated stats over `n` measured steps.
fn per_step(stats: &ExecStats, n: usize) -> StepClocks {
    let nf = n as f64;
    StepClocks {
        wall_s: stats.wall_s / nf,
        virtual_time_s: stats.virtual_time_s / nf,
        spill_bytes_written: stats.spill_bytes_written / n as u64,
        bytes_shuffled: stats.bytes_shuffled / n as u64,
        shuffles_elided: stats.shuffles_elided / n as u64,
    }
}

/// Per-step clocks of the fig2 NNMF workload (V ≈ W·H over `chunk`-sized
/// blocks), measured like [`gcn_step_clocks`].
#[allow(clippy::too_many_arguments)]
pub fn nnmf_step_clocks(
    n: usize,
    d: usize,
    chunk: usize,
    workers: usize,
    steps: usize,
    parallel_comm: bool,
    budget: Option<u64>,
    factorize: bool,
    backend: &dyn KernelBackend,
) -> Result<StepClocks, DistError> {
    nnmf_step_clocks_faulted(
        n,
        d,
        chunk,
        workers,
        steps,
        parallel_comm,
        budget,
        factorize,
        None,
        backend,
    )
    .map(|f| f.clocks)
}

/// [`nnmf_step_clocks`] with an optional scripted [`FaultPlan`] — the
/// faulty bench column (see [`gcn_step_clocks_faulted`]).
#[allow(clippy::too_many_arguments)]
pub fn nnmf_step_clocks_faulted(
    n: usize,
    d: usize,
    chunk: usize,
    workers: usize,
    steps: usize,
    parallel_comm: bool,
    budget: Option<u64>,
    factorize: bool,
    fault_plan: Option<FaultPlan>,
    backend: &dyn KernelBackend,
) -> Result<FaultedClocks, DistError> {
    let nb = n.div_ceil(chunk);
    let db = d.div_ceil(chunk);
    let mut rng = Prng::new(5);
    let v = crate::data::matrices::random_block_matrix(n, n, chunk, &mut rng, true);
    let (w, h) = nnmf::init_factors(nb, db, nb, chunk, &mut rng);
    let q = nnmf::loss_query(Arc::new(v), n * n);
    let mut ccfg = ClusterConfig::new(workers)
        .with_policy(MemPolicy::Spill)
        .with_parallel_comm(parallel_comm)
        .with_factorize(factorize);
    if let Some(b) = budget {
        ccfg = ccfg.with_budget(b);
    }
    if let Some(plan) = fault_plan {
        ccfg = ccfg.with_fault_plan(plan);
    }
    // Both factors are parameters: the trainer still charges their
    // ingest per step, but every taped intermediate stays sharded.
    let sess = Session::with_backend(ccfg, backend.for_worker());
    let mut trainer = sess
        .trainer(
            ModelSpec::new(q)
                .param_with_layout("W", 2, SlotLayout::HashFull)
                .param_with_layout("H", 2, SlotLayout::HashFull),
        )
        .map_err(to_dist_err)?;
    let mut stats = ExecStats::default();
    let mut out = FaultedClocks::default();
    for step in 0..steps.max(2) {
        let res = trainer.step(&[("W", &w), ("H", &h)]).map_err(to_dist_err)?;
        out.loss_bits.push(res.loss.to_bits());
        out.stage_retries += res.stats.stage_retries;
        out.faults_injected += res.stats.faults_injected;
        if step > 0 {
            stats.merge(&res.stats);
        }
    }
    out.clocks = per_step(&stats, steps.max(2) - 1);
    Ok(out)
}

/// One measured point of the streaming-update workload: a memoized
/// frame replaying small signed delta batches through the incremental
/// engine vs a full recompute of the same merged catalog.
#[derive(Clone, Copy, Debug)]
pub struct DeltaBenchPoint {
    pub workers: usize,
    /// Measured wall seconds per update round for the delta path: one
    /// long-lived frame re-collected after each insert batch (the
    /// engine replays the batch against the previous tape).
    pub wall_s_delta: f64,
    /// Measured wall seconds per update round for the baseline: a fresh
    /// frame opened over the same merged catalog every round, so every
    /// stage recomputes from scratch.
    pub wall_s_recompute: f64,
    /// Rows in each insert batch (the update rate × base size).
    pub delta_rows_per_round: u64,
    /// Shards the delta path served from previous tapes across all
    /// rounds — zero would mean the replay silently recomputed.
    pub shards_reused: u64,
    /// Whether every round's delta-maintained result was bitwise equal
    /// to the recomputed one (the smoke mode exits nonzero otherwise).
    pub bitwise: bool,
}

/// Integer-valued `c×c` chunks for the given keys (sums stay exact in
/// f32, so the delta-vs-recompute comparison is bitwise, not approximate).
fn int_rel(keys: impl Iterator<Item = crate::ra::Key>, c: usize, rng: &mut Prng) -> Relation {
    let mut r = Relation::new();
    for k in keys {
        let v = (rng.next_u64() % 9 + 1) as f32;
        r.insert(k, crate::ra::Chunk::filled(c, c, v));
    }
    r
}

fn rel_bits_eq(a: &Relation, b: &Relation) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, va)| {
            b.get(k).map_or(false, |vb| {
                va.shape() == vb.shape()
                    && va
                        .data()
                        .iter()
                        .zip(vb.data().iter())
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            })
        })
}

/// Per-round clocks of the streaming-update workload: Σ over a
/// co-partitioned `R(a,b) ⋈ S(a,c)` with `n` base rows in `groups`
/// groups, taking `rounds` insert batches of `update_frac · n` rows
/// each. `wall_s_delta` re-collects one memoized frame (the incremental
/// engine replays each batch as a per-shard suffix through the ⋈ and
/// folds it into the cached Σ); `wall_s_recompute` opens a fresh frame
/// over the same merged catalog every round — the full-recompute
/// baseline the delta path is proven bitwise against.
pub fn delta_update_clocks(
    n: i64,
    groups: i64,
    chunk: usize,
    update_frac: f64,
    rounds: usize,
    workers: usize,
) -> Result<DeltaBenchPoint, DistError> {
    use crate::kernels::{AggKernel, BinaryKernel};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::{JoinPred, Key, KeyProj, KeyProj2, Sel2};
    use std::time::Instant;

    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    let q = qb.finish(a);

    let mut rng = Prng::new(0xDE17A);
    let r0 = int_rel((0..n).map(|i| Key::k2(i % groups, i)), chunk, &mut rng);
    let s0 = int_rel((0..groups).map(|g| Key::k2(g, n + g)), chunk, &mut rng);
    let mk = || -> Result<Session, SessionError> {
        let sess = Session::new(ClusterConfig::new(workers).with_factorize(false));
        sess.register_with_layout("R", &["a", "b"], &r0, &SlotLayout::HashOn(vec![0]))?;
        sess.register_with_layout("S", &["a", "c"], &s0, &SlotLayout::HashOn(vec![0]))?;
        Ok(sess)
    };
    // Warm both sessions (partition caches, worker pools, and the live
    // frame's memoized tape) so the rounds measure steady-state updates.
    let live = mk().map_err(to_dist_err)?;
    let frame = live.query(&q).map_err(to_dist_err)?;
    frame.collect().map_err(to_dist_err)?;
    let base = mk().map_err(to_dist_err)?;
    base.query(&q)
        .map_err(to_dist_err)?
        .collect()
        .map_err(to_dist_err)?;

    let batch_rows = ((n as f64 * update_frac).ceil() as i64).max(1);
    let reused_before = live.stats().shards_reused;
    let (mut t_delta, mut t_recompute, mut bitwise) = (0.0f64, 0.0f64, true);
    for round in 0..rounds {
        let first = n + groups + round as i64 * batch_rows;
        let batch: Vec<(Key, crate::ra::Chunk)> = (0..batch_rows)
            .map(|i| {
                let id = first + i;
                let v = (rng.next_u64() % 9 + 1) as f32;
                (Key::k2(id % groups, id), crate::ra::Chunk::filled(chunk, chunk, v))
            })
            .collect();
        live.insert("R", batch.clone()).map_err(to_dist_err)?;
        base.insert("R", batch).map_err(to_dist_err)?;
        let t0 = Instant::now();
        let got = frame.collect().map_err(to_dist_err)?;
        t_delta += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let want = base
            .query(&q)
            .map_err(to_dist_err)?
            .collect()
            .map_err(to_dist_err)?;
        t_recompute += t0.elapsed().as_secs_f64();
        bitwise &= rel_bits_eq(&got, &want);
    }
    Ok(DeltaBenchPoint {
        workers,
        wall_s_delta: t_delta / rounds as f64,
        wall_s_recompute: t_recompute / rounds as f64,
        delta_rows_per_round: batch_rows as u64,
        shards_reused: live.stats().shards_reused - reused_before,
        bitwise,
    })
}

/// One measured point of the skew workload: the same Zipf-keyed Σ-over-⋈
/// executed by an oblivious session and by a skew-aware one (ingest
/// sampler on) over bitwise-identical catalogs.
#[derive(Clone, Copy, Debug)]
pub struct SkewBenchPoint {
    pub workers: usize,
    /// Measured wall seconds per query, oblivious plan (no hot-key
    /// annotation: the join runs wherever the hash placement piles it).
    pub wall_s_oblivious: f64,
    /// Measured wall seconds per query with the ingest sampler on and a
    /// skew join strategy available to the planner.
    pub wall_s_skew: f64,
    /// Hot keys the ingest sampler recorded across the catalog.
    pub hot_keys_detected: u64,
    /// Per-query rows routed through salted buckets (or pinned at their
    /// source under the broadcast strategy).
    pub rows_salted: u64,
    /// Per-query bytes of hot-row replicas the skew strategy paid.
    pub bytes_hot_replicated: u64,
    /// Largest per-worker join-input load of the ⋈ stage, oblivious plan.
    pub max_shard_bytes_oblivious: u64,
    /// Same under the skew plan — strictly smaller whenever a skew
    /// strategy fired (the whole point of paying the replicas).
    pub max_shard_bytes_skew: u64,
    /// Whether the traced skew plan actually picked a skew strategy.
    pub skew_fired: bool,
    /// Whether the two sessions' outputs were bitwise identical, per
    /// shard and gathered (the smoke mode exits nonzero otherwise).
    pub bitwise: bool,
}

/// Clocks of the skew workload: Σ over a co-partitioned
/// `R(a,b) ⋈ S(a,c)` where R's `n` join keys are drawn Zipf(`zipf_s`)
/// over `groups` values — a power-law head that piles one worker high
/// under oblivious hashing. Both sessions share the network model (zero
/// latency, modest bandwidth, so the planner's straggler term is
/// byte-dominated at bench scale) and bitwise-identical catalogs; only
/// `skew_threshold` differs, so any output difference is a skew-path
/// bug, not workload noise.
pub fn zipf_skew_clocks(
    n: i64,
    groups: i64,
    chunk: usize,
    zipf_s: f64,
    threshold: f64,
    workers: usize,
    rounds: usize,
) -> Result<SkewBenchPoint, DistError> {
    use crate::dist::NetModel;
    use crate::kernels::{AggKernel, BinaryKernel};
    use crate::ra::expr::QueryBuilder;
    use crate::ra::{JoinPred, Key, KeyProj, KeyProj2, Sel2};
    use std::time::Instant;

    let mut qb = QueryBuilder::new();
    let r = qb.scan(0, "R");
    let s = qb.scan(1, "S");
    let j = qb.join(
        JoinPred::on(vec![(0, 0)]),
        KeyProj2(vec![Sel2::L(0), Sel2::L(1), Sel2::R(1)]),
        BinaryKernel::Mul,
        r,
        s,
    );
    let a = qb.agg(KeyProj::take(&[0]), AggKernel::Sum, j);
    let q = qb.finish(a);

    let mut rng = Prng::new(0x5C3A);
    let r_keys: Vec<Key> = (0..n)
        .map(|i| Key::k2(rng.zipf(groups as u64, zipf_s) as i64, i))
        .collect();
    let r0 = int_rel(r_keys.into_iter(), chunk, &mut rng);
    let s0 = int_rel((0..groups).map(|g| Key::k2(g, n + g)), chunk, &mut rng);
    let net = NetModel {
        bandwidth_bps: 1e6,
        latency_s: 0.0,
    };
    let mk = |thresh: Option<f64>| -> Result<Session, SessionError> {
        let mut cfg = ClusterConfig::new(workers).with_factorize(false).with_net(net);
        if let Some(t) = thresh {
            cfg = cfg.with_skew_threshold(t);
        }
        let sess = Session::new(cfg);
        sess.register_with_layout("R", &["a", "b"], &r0, &SlotLayout::HashOn(vec![0]))?;
        sess.register_with_layout("S", &["a", "c"], &s0, &SlotLayout::HashOn(vec![0]))?;
        Ok(sess)
    };
    // One measured closure per session: warm once (pool spin-up, caches),
    // then time `rounds` fresh frames — each a full plan + execution.
    let measure = |sess: &Session| -> Result<f64, SessionError> {
        sess.query(&q)?.collect()?;
        let t0 = Instant::now();
        for _ in 0..rounds.max(1) {
            sess.query(&q)?.collect()?;
        }
        Ok(t0.elapsed().as_secs_f64() / rounds.max(1) as f64)
    };

    let obl = mk(None).map_err(to_dist_err)?;
    let wall_obl = measure(&obl).map_err(to_dist_err)?;
    let obl_frame = obl.query(&q).map_err(to_dist_err)?;
    let (obl_trace, _) = obl_frame.trace().map_err(to_dist_err)?;
    let (obl_out, _) = obl_frame.collect_partitioned().map_err(to_dist_err)?;
    let max_obl = obl_trace
        .iter()
        .filter(|t| t.op == "⋈")
        .map(|t| t.max_shard_bytes)
        .max()
        .unwrap_or(0);

    let skew = mk(Some(threshold)).map_err(to_dist_err)?;
    let hot_keys_detected = skew.stats().hot_keys_detected;
    let wall_skew = measure(&skew).map_err(to_dist_err)?;
    let skew_frame = skew.query(&q).map_err(to_dist_err)?;
    let (skew_trace, run_stats) = skew_frame.trace().map_err(to_dist_err)?;
    let (skew_out, _) = skew_frame.collect_partitioned().map_err(to_dist_err)?;
    let max_skew = skew_trace
        .iter()
        .filter(|t| t.op == "⋈")
        .map(|t| t.max_shard_bytes)
        .max()
        .unwrap_or(0);
    let skew_fired = skew_trace
        .iter()
        .any(|t| matches!(&t.strategy, Some(s) if format!("{s:?}").contains("Skew")));

    let mut bitwise = obl_out.workers() == skew_out.workers();
    for wi in 0..obl_out.workers().min(skew_out.workers()) {
        bitwise &= rel_bits_eq(&obl_out.shards[wi], &skew_out.shards[wi]);
    }
    bitwise &= rel_bits_eq(&obl_out.gather(), &skew_out.gather());

    Ok(SkewBenchPoint {
        workers,
        wall_s_oblivious: wall_obl,
        wall_s_skew: wall_skew,
        hot_keys_detected,
        rows_salted: run_stats.rows_salted,
        bytes_hot_replicated: run_stats.bytes_hot_replicated,
        max_shard_bytes_oblivious: max_obl,
        max_shard_bytes_skew: max_skew,
        skew_fired,
        bitwise,
    })
}

/// One measured point of the serving workload: `clients` concurrent
/// [`crate::serve::Client`] handles hammering one shared engine with a
/// repeated query mix.
#[derive(Clone, Copy, Debug)]
pub struct ServeBenchPoint {
    pub workers: usize,
    pub clients: usize,
    /// Measured wall seconds per query of the cold pass (cache empty —
    /// every statement lowers and executes on the pool).
    pub wall_s_cold: f64,
    /// Measured wall seconds per query of the warm pass (every repeat
    /// served from the result cache), across all concurrent clients.
    pub wall_s_warm: f64,
    /// Result-cache hits recorded during the warm pass — zero would
    /// mean the cache silently stopped serving.
    pub cache_hits: u64,
    /// Probe: most admission slots ever held at once (must stay ≤ the
    /// configured `max_inflight`).
    pub max_inflight_seen: usize,
    /// Warm-pass queries per second across all clients.
    pub queries_per_s: f64,
}

fn serve_to_dist(e: crate::serve::ServeError) -> DistError {
    match e {
        crate::serve::ServeError::Session(s) => to_dist_err(s),
        other => DistError::Other(anyhow::anyhow!("{other}")),
    }
}

/// Clocks of the serving workload: one [`crate::serve::Engine`] over `w`
/// workers, a three-statement mix (co-partitioned ⋈ + Σ, and two maps)
/// over `R(a,b)`/`S(a,c)` with `n` base rows in `groups` groups. The
/// cold pass fills the cache (each statement executed once); the warm
/// pass runs `clients` threads × `repeats` repetitions of the whole mix,
/// every query a result-cache hit.
pub fn serve_throughput_clocks(
    n: i64,
    groups: i64,
    chunk: usize,
    workers: usize,
    clients: usize,
    repeats: usize,
) -> Result<ServeBenchPoint, DistError> {
    use crate::ra::Key;
    use crate::serve::Engine;
    use std::time::Instant;

    let mut rng = Prng::new(0x5E47E);
    let r0 = int_rel((0..n).map(|i| Key::k2(i % groups, i)), chunk, &mut rng);
    let s0 = int_rel((0..groups).map(|g| Key::k2(g, n + g)), chunk, &mut rng);
    let engine = Engine::new(ClusterConfig::new(workers));
    let c0 = engine.client();
    c0.register_with_layout("R", &["a", "b"], &r0, &SlotLayout::HashOn(vec![0]))
        .map_err(serve_to_dist)?;
    c0.register_with_layout("S", &["a", "c"], &s0, &SlotLayout::HashOn(vec![0]))
        .map_err(serve_to_dist)?;
    let statements = [
        "SELECT R.a, SUM(mul(R.val, S.val)) FROM R, S WHERE R.a = S.a GROUP BY R.a",
        "SELECT R.a, R.b, relu(R.val) FROM R",
        "SELECT S.a, S.c, logistic(S.val) FROM S",
    ];
    // Cold: fill the cache (each statement lowers + executes once).
    let t0 = Instant::now();
    for q in &statements {
        c0.query(q).map_err(serve_to_dist)?;
    }
    let wall_cold = t0.elapsed().as_secs_f64();
    let hits_before = engine.stats().cache_hits;
    // Warm: concurrent clients replay the same mix; every query hits.
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<(), DistError> {
        let mut handles = Vec::new();
        for _ in 0..clients {
            let client = engine.client();
            handles.push(scope.spawn(move || -> Result<(), crate::serve::ServeError> {
                for _ in 0..repeats {
                    for q in &statements {
                        client.query(q)?;
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("serve client thread").map_err(serve_to_dist)?;
        }
        Ok(())
    })?;
    let wall_warm = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    let warm_queries = (clients * repeats * statements.len()) as f64;
    Ok(ServeBenchPoint {
        workers,
        clients,
        wall_s_cold: wall_cold / statements.len() as f64,
        wall_s_warm: wall_warm / warm_queries,
        cache_hits: stats.cache_hits - hits_before,
        max_inflight_seen: stats.max_inflight_seen,
        queries_per_s: if wall_warm > 0.0 {
            warm_queries / wall_warm
        } else {
            0.0
        },
    })
}

/// Serialize the perf trajectory to the JSON shape the repo tracks in
/// `BENCH_dist.json` (no serde: the format is flat).
pub fn bench_json(
    mode: &str,
    host_cores: usize,
    workloads: &[(String, Vec<DistBenchPoint>)],
    delta: &[DeltaBenchPoint],
    serve: &[ServeBenchPoint],
    skew: &[SkewBenchPoint],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"dist\",\n");
    s.push_str(&format!("  \"mode\": \"{mode}\",\n"));
    s.push_str(&format!("  \"host_cores\": {host_cores},\n"));
    s.push_str("  \"delta_update\": [\n");
    for (pi, p) in delta.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"wall_s_delta\": {:.6}, \"wall_s_recompute\": {:.6}, \"delta_rows_per_round\": {}, \"shards_reused\": {}, \"bitwise\": {}}}{}\n",
            p.workers,
            p.wall_s_delta,
            p.wall_s_recompute,
            p.delta_rows_per_round,
            p.shards_reused,
            p.bitwise,
            if pi + 1 < delta.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"serve_throughput\": [\n");
    for (pi, p) in serve.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"clients\": {}, \"wall_s_cold\": {:.6}, \"wall_s_warm\": {:.6}, \"cache_hits\": {}, \"max_inflight_seen\": {}, \"queries_per_s\": {:.1}}}{}\n",
            p.workers,
            p.clients,
            p.wall_s_cold,
            p.wall_s_warm,
            p.cache_hits,
            p.max_inflight_seen,
            p.queries_per_s,
            if pi + 1 < serve.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"zipf_skew\": [\n");
    for (pi, p) in skew.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workers\": {}, \"wall_s_oblivious\": {:.6}, \"wall_s_skew\": {:.6}, \"hot_keys_detected\": {}, \"rows_salted\": {}, \"bytes_hot_replicated\": {}, \"max_shard_bytes_oblivious\": {}, \"max_shard_bytes_skew\": {}, \"skew_fired\": {}, \"bitwise\": {}}}{}\n",
            p.workers,
            p.wall_s_oblivious,
            p.wall_s_skew,
            p.hot_keys_detected,
            p.rows_salted,
            p.bytes_hot_replicated,
            p.max_shard_bytes_oblivious,
            p.max_shard_bytes_skew,
            p.skew_fired,
            p.bitwise,
            if pi + 1 < skew.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"workloads\": [\n");
    for (wi, (name, points)) in workloads.iter().enumerate() {
        s.push_str(&format!("    {{\"name\": \"{name}\", \"results\": [\n"));
        for (pi, p) in points.iter().enumerate() {
            s.push_str(&format!(
                "      {{\"workers\": {}, \"wall_s\": {:.6}, \"wall_s_driver_comm\": {:.6}, \"wall_s_spill\": {:.6}, \"spill_bytes_written\": {}, \"wall_s_factorized\": {:.6}, \"wall_s_faulty\": {:.6}, \"bytes_shuffled\": {}, \"bytes_shuffled_factorized\": {}, \"shuffles_elided\": {}, \"virtual_time_s\": {:.6}, \"speedup\": {:.3}}}{}\n",
                p.workers,
                p.wall_s,
                p.wall_s_driver_comm,
                p.wall_s_spill,
                p.spill_bytes_written,
                p.wall_s_factorized,
                p.wall_s_faulty,
                p.bytes_shuffled,
                p.bytes_shuffled_factorized,
                p.shuffles_elided,
                p.virtual_time_s,
                p.speedup,
                if pi + 1 < points.len() { "," } else { "" }
            ));
        }
        s.push_str(&format!(
            "    ]}}{}\n",
            if wi + 1 < workloads.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Format a `Result<f64, DistError>` / `BaselineResult` into a table cell.
pub fn cell(r: &Result<f64, DistError>) -> String {
    match r {
        Ok(t) => format!("{t:.3}s"),
        Err(DistError::Oom { .. }) => "OOM".to_string(),
        Err(e) => format!("ERR({e})"),
    }
}

pub fn bcell(r: &BaselineResult) -> String {
    r.display()
}

/// Print a markdown-ish row.
pub fn print_row(name: &str, cells: &[String]) {
    let body = cells
        .iter()
        .map(|c| format!("{c:>12}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("{name:<14} {body}");
}

pub fn print_header(title: &str, workers: &[usize]) {
    println!("\n=== {title} ===");
    let cols = workers
        .iter()
        .map(|w| format!("{w:>12}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("{:<14} {cols}", "system\\W");
}
