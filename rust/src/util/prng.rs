//! SplitMix64-based PRNG: deterministic, seedable, dependency-free.
//! Used for synthetic data generation, parameter init, dropout masks and
//! the built-in property-testing helper.

#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng {
            state: seed ^ 0x5851_f42d_4c95_7f2d,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-9);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (approximate
    /// inverse-CDF sampling; good enough for skewed workload generation).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        let u = self.next_f32() as f64;
        if s == 1.0 {
            let hn = (n as f64).ln().max(1.0);
            (((u * hn).exp() - 1.0).min(n as f64 - 1.0)) as u64
        } else {
            let e = 1.0 - s;
            let nf = n as f64;
            let x = ((nf.powf(e) - 1.0) * u + 1.0).powf(1.0 / e) - 1.0;
            (x.min(nf - 1.0).max(0.0)) as u64
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        let mut out = crate::util::FxHashSet::default();
        while out.len() < k {
            out.insert(self.below(n as u64) as usize);
        }
        let mut v: Vec<usize> = out.into_iter().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            let x = p.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| p.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_skew() {
        let mut p = Prng::new(3);
        let mut c0 = 0;
        for _ in 0..10_000 {
            if p.zipf(1000, 1.1) == 0 {
                c0 += 1;
            }
        }
        // Head element should be heavily over-represented vs uniform (10).
        assert!(c0 > 200, "c0={c0}");
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(5);
        for _ in 0..1000 {
            assert!(p.below(17) < 17);
        }
    }
}
