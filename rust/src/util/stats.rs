//! Tiny timing/statistics helpers for the bench harness (criterion is not
//! available offline; bench binaries use `harness = false` + this module).

use std::time::Instant;

/// Run `f` `iters` times after `warmup` warmup runs; return per-iter stats.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing::from_samples(samples)
}

#[derive(Debug, Clone)]
pub struct Timing {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
    pub n: usize,
}

impl Timing {
    pub fn from_samples(mut s: Vec<f64>) -> Timing {
        assert!(!s.is_empty());
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Timing {
            mean,
            min: s[0],
            max: s[n - 1],
            stddev: var.sqrt(),
            n,
        }
    }
}

/// Human format for seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = Timing::from_samples(vec![1.0, 2.0, 3.0]);
        assert!((t.mean - 2.0).abs() < 1e-12);
        assert_eq!(t.min, 1.0);
        assert_eq!(t.max, 3.0);
        assert_eq!(t.n, 3);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(0.0025), "2.500ms");
        assert_eq!(fmt_secs(0.0000025), "2.500us");
    }
}
