//! Small self-contained utilities (the vendored registry has no rand /
//! fxhash / criterion, so we carry our own minimal equivalents).

pub mod fxhash;
pub mod prng;
pub mod stats;

pub use fxhash::{FxBuildHasher, FxHashMap, FxHashSet};
pub use prng::Prng;
