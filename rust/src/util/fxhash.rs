//! FxHash (the rustc hash): a fast, non-cryptographic hash for small keys.
//! Used for all relation indexes — tuple keys are a handful of i64s and
//! SipHash (std default) is the single biggest cost in hash joins.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-xor hasher as used by rustc (Fx).
#[derive(Default, Clone, Copy)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add_to_hash(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_i64(&mut self, i: i64) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

/// Hash a single u64 (used for hash-partitioning and dropout masks).
#[inline]
pub fn hash_u64(x: u64) -> u64 {
    // SplitMix64 finalizer — good avalanche for partitioning.
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_distinct_hashes() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(hash_u64(i)));
        }
    }

    #[test]
    fn fxmap_works() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(i, i * 2);
        }
        assert_eq!(m[&500], 1000);
    }
}
