"""AOT path: every registered kernel/shape lowers to HLO text that the
XLA CPU client can compile and that computes the oracle's numbers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import build, shape_tag, to_hlo_text
from compile.model import KERNELS, shape_sets


def test_shape_sets_cover_only_known_kernels():
    sets = shape_sets(64, 40)
    unknown = set(sets) - set(KERNELS)
    assert not unknown, f"shape set for unregistered kernels: {unknown}"


def test_shape_sets_arity_consistent():
    sets = shape_sets(64, 40)
    for name, shapes_list in sets.items():
        _, arity = KERNELS[name]
        for shapes in shapes_list:
            assert len(shapes) == arity, f"{name}: {shapes}"


def test_hlo_text_parses_and_mentions_entry():
    text = to_hlo_text(model.add, [(4, 4), (4, 4)])
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_small_build_roundtrip(tmp_path):
    """Build a tiny artifact dir (chunk=8): every artifact must re-parse
    through the HLO text parser (the exact path the rust loader uses; the
    numeric execution cross-check lives in rust's `runtime` integration
    test, which runs these artifacts through the PJRT C API)."""
    out = str(tmp_path / "artifacts")
    n = build(out, chunk=8, labels=4, verbose=False)
    assert n > 30
    manifest = open(os.path.join(out, "manifest.tsv")).read().strip().split("\n")
    assert len(manifest) == n
    from jax._src.lib import xla_client as xc

    for name, shapes in [
        ("matmul", [(8, 8), (8, 8)]),
        ("logistic", [(8, 8)]),
        ("softmax_xent_rows", [(8, 4), (8, 4)]),
    ]:
        fname = f"{name}__{shape_tag(shapes)}.hlo.txt"
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        text = open(path).read()
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.as_serialized_hlo_module_proto()  # parses + serializes


def test_manifest_filenames_unique():
    sets = shape_sets(64, 40)
    seen = set()
    for name, shapes_list in sets.items():
        for shapes in shapes_list:
            f = f"{name}__{shape_tag(shapes)}"
            assert f not in seen, f"duplicate artifact {f}"
            seen.add(f)
