"""Kernel correctness: Pallas (L1) and exported model kernels (L2) vs the
pure-jnp oracle, and explicit derivative kernels vs jax.grad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul_pallas import matmul as routed_matmul
from compile.kernels.matmul_pallas import matmul_pallas, pick_blocks
from compile import model


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


# ------------------------------------------------------------------ L1

@pytest.mark.parametrize(
    "m,k,n,bm,bk,bn",
    [
        (32, 32, 32, 32, 32, 32),
        (64, 64, 64, 32, 32, 32),
        (64, 32, 96, 16, 16, 32),
        (128, 64, 32, 32, 32, 32),
    ],
)
def test_pallas_matmul_matches_ref(m, k, n, bm, bk, bn):
    x, y = rand((m, k), 1), rand((k, n), 2)
    got = matmul_pallas(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    k=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_pallas_matmul_hypothesis_shapes(m, k, n, seed):
    x, y = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(
        routed_matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 7), k=st.integers(1, 7), n=st.integers(1, 7),
    seed=st.integers(0, 2**16),
)
def test_routed_matmul_falls_back_on_tiny_shapes(m, k, n, seed):
    x, y = rand((m, k), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(
        routed_matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4
    )


def test_pick_blocks_divides():
    for dims in [(64, 64, 64), (48, 32, 96), (1, 5, 7), (128, 8, 24)]:
        bm, bk, bn = pick_blocks(*dims)
        assert dims[0] % bm == 0 and dims[1] % bk == 0 and dims[2] % bn == 0


def test_pallas_rejects_non_divisible():
    with pytest.raises(AssertionError):
        matmul_pallas(rand((33, 32)), rand((32, 32)), bm=32, bn=32, bk=32)


# ------------------------------------------------------------------ L2

def test_model_matmuls_route_through_pallas_and_match_ref():
    a, b = rand((64, 64), 3), rand((64, 64), 4)
    np.testing.assert_allclose(
        model.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        model.matmul_tn(a, b), ref.matmul_tn(a, b), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        model.matmul_nt(a, b), ref.matmul_nt(a, b), rtol=1e-4, atol=1e-5
    )


UNARY = [
    "neg", "logistic", "relu", "tanh", "exp", "square", "sqrt",
    "sum_all", "row_sum", "softmax_rows", "transpose",
]


@pytest.mark.parametrize("name", UNARY)
def test_unary_kernels_finite_and_shaped(name):
    fn, arity = model.KERNELS[name]
    assert arity == 1
    x = rand((8, 8), 5, scale=0.7)
    out = fn(x)
    assert np.all(np.isfinite(out))


# ------------------------------------------- derivatives vs jax.grad

@pytest.mark.parametrize(
    "fwd,dkern",
    [
        (ref.logistic, ref.d_logistic),
        (ref.tanh, ref.d_tanh),
        (ref.exp, ref.d_exp),
        (ref.square, ref.d_square),
    ],
)
def test_unary_derivative_matches_jax_grad(fwd, dkern):
    x = rand((4, 5), 7, scale=0.5)
    g = rand((4, 5), 8)
    want = jax.vjp(fwd, x)[1](g)[0]
    got = dkern(g, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_bce_partial_matches_jax_grad():
    yhat = jnp.clip(jnp.abs(rand((6, 1), 9)), 0.05, 0.95)
    y = (rand((6, 1), 10) > 0).astype(jnp.float32)
    want = jax.vjp(lambda p: ref.bce_loss(p, y), yhat)[1](jnp.ones_like(yhat))[0]
    got = ref.d_bce_dyhat(yhat, y) * 1.0
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_softmax_xent_partial_matches_jax_grad():
    logits = rand((5, 8), 11)
    onehot = jax.nn.one_hot(jnp.arange(5) % 8, 8)
    loss = lambda l: jnp.sum(ref.softmax_xent_rows(l, onehot))
    want = jax.grad(loss)(logits)
    got = ref.d_softmax_xent_dl(logits, onehot)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_matmul_vjps_match_jax():
    a, b = rand((6, 4), 12), rand((4, 3), 13)
    g = rand((6, 3), 14)
    _, vjp = jax.vjp(ref.matmul, a, b)
    da, db = vjp(g)
    np.testing.assert_allclose(ref.matmul_nt(g, b), da, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(ref.matmul_tn(a, g), db, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 12), cols=st.integers(1, 12), seed=st.integers(0, 2**16)
)
def test_elementwise_binary_hypothesis(rows, cols, seed):
    l = rand((rows, cols), seed, 0.8)
    r = rand((rows, cols), seed + 1, 0.8) + 2.5  # keep divisor away from 0
    np.testing.assert_allclose(ref.add(l, r), np.asarray(l) + np.asarray(r))
    np.testing.assert_allclose(ref.mul(l, r), np.asarray(l) * np.asarray(r))
    np.testing.assert_allclose(
        ref.div(l, r), np.asarray(l) / np.asarray(r), rtol=1e-5
    )
    np.testing.assert_allclose(
        ref.squared_diff(l, r), (np.asarray(l) - np.asarray(r)) ** 2, rtol=1e-5
    )


def test_softmax_xent_masked_rows_zero():
    logits = rand((3, 4), 15)
    onehot = jnp.zeros((3, 4))
    out = ref.softmax_xent_rows(logits, onehot)
    np.testing.assert_allclose(out, jnp.zeros((3, 1)))
