"""AOT lowering: JAX/Pallas kernels -> HLO text artifacts + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Output layout:

    artifacts/
      manifest.tsv          name \t arity \t in_shapes \t file
      <name>__<r>x<c>[__<r>x<c>].hlo.txt

Usage: python -m compile.aot --out ../artifacts [--chunk 64] [--labels 40]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import KERNELS, shape_sets


def to_hlo_text(fn, arg_shapes) -> str:
    """Lower a jitted fn at the given arg shapes to HLO text."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in arg_shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_tag(shapes) -> str:
    return "__".join(f"{r}x{c}" for (r, c) in shapes)


def build(out_dir: str, chunk: int, labels: int, verbose: bool = True) -> int:
    os.makedirs(out_dir, exist_ok=True)
    sets = shape_sets(chunk, labels)
    manifest_lines = []
    n = 0
    for name, (fn, arity) in KERNELS.items():
        for shapes in sets.get(name, []):
            assert len(shapes) == arity, f"{name}: arity mismatch {shapes}"
            fname = f"{name}__{shape_tag(shapes)}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text = to_hlo_text(fn, shapes)
            with open(path, "w") as f:
                f.write(text)
            shape_sig = ",".join(f"{r}x{c}" for (r, c) in shapes)
            manifest_lines.append(f"{name}\t{arity}\t{shape_sig}\t{fname}")
            n += 1
            if verbose:
                print(f"  {fname}  ({len(text)} B)")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    if verbose:
        print(f"wrote {n} artifacts + manifest.tsv to {out_dir}")
    return n


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--labels", type=int, default=40)
    args = ap.parse_args()
    build(args.out, args.chunk, args.labels)


if __name__ == "__main__":
    main()
