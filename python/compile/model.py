"""L2: the chunk kernel functions exported to the rust engine.

The paper's tensor-relational extension (Appendix A) keeps the RA autodiff
at the relational level and delegates *kernel-function* differentiation to
a conventional tensor autodiff — JAX here. This module defines every
kernel the rust engine dispatches (forward kernels, partial-derivative
kernels and chain/vjp kernels), with the matmul family routed through the
L1 Pallas kernel so the blocked-matmul schedule lowers into the same HLO.

`aot.py` lowers each entry of `KERNELS` for each shape in the artifact
set; the rust `runtime::XlaBackend` executes them from the join/selection
hot paths. Python never runs at serve/train time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.matmul_pallas import matmul as pallas_matmul

# ------------------------------------------------------------------
# Forward kernels (matmuls go through the L1 Pallas kernel)
# ------------------------------------------------------------------

def matmul(l, r):
    return pallas_matmul(l, r)


def matmul_tn(l, r):
    return pallas_matmul(l.T, r)


def matmul_nt(l, r):
    return pallas_matmul(l, r.T)


# Elementwise/other kernels are the oracle definitions themselves — they
# lower to single fused HLO loops; nothing to hand-tile.
add = ref.add
sub = ref.sub
mul = ref.mul
div = ref.div
bce_loss = ref.bce_loss
squared_diff = ref.squared_diff
softmax_xent_rows = ref.softmax_xent_rows
row_broadcast_mul = ref.row_broadcast_mul
scalar_mul = ref.scalar_mul
sum_mul = ref.sum_mul

neg = ref.neg
logistic = ref.logistic
relu = ref.relu
tanh = ref.tanh
exp = ref.exp
log = ref.log
square = ref.square
sqrt = ref.sqrt
sum_all = ref.sum_all
row_sum = ref.row_sum
softmax_rows = ref.softmax_rows
transpose = ref.transpose

d_logistic = ref.d_logistic
d_relu = ref.d_relu
d_tanh = ref.d_tanh
d_exp = ref.d_exp
d_log = ref.d_log
d_square = ref.d_square
d_sqrt = ref.d_sqrt
d_softmax_rows = ref.d_softmax_rows
broadcast_fst = ref.broadcast_fst
broadcast_rows_fst = ref.broadcast_rows_fst
d_div_l = ref.d_div_l
d_div_r = ref.d_div_r
d_bce_dyhat = ref.d_bce_dyhat
d_squared_diff_l = ref.d_squared_diff_l
d_softmax_xent_dl = ref.d_softmax_xent_dl


# ------------------------------------------------------------------
# Artifact registry: kernel name -> (fn, arity).
# Names must match rust's `UnaryKernel::name()` / `BinaryKernel::name()`.
# ------------------------------------------------------------------

KERNELS: dict[str, tuple] = {
    # binary forward
    "add": (add, 2),
    "sub": (sub, 2),
    "mul": (mul, 2),
    "div": (div, 2),
    "matmul": (matmul, 2),
    "matmul_tn": (matmul_tn, 2),
    "matmul_nt": (matmul_nt, 2),
    "bce_loss": (bce_loss, 2),
    "squared_diff": (squared_diff, 2),
    "softmax_xent_rows": (softmax_xent_rows, 2),
    "row_broadcast_mul": (row_broadcast_mul, 2),
    "scalar_mul": (scalar_mul, 2),
    "sum_mul": (sum_mul, 2),
    # unary forward
    "neg": (neg, 1),
    "logistic": (logistic, 1),
    "relu": (relu, 1),
    "tanh": (tanh, 1),
    "exp": (exp, 1),
    "log": (log, 1),
    "square": (square, 1),
    "sqrt": (sqrt, 1),
    "sum_all": (sum_all, 1),
    "row_sum": (row_sum, 1),
    "softmax_rows": (softmax_rows, 1),
    "transpose": (transpose, 1),
    # derivative / chain kernels
    "d_logistic": (d_logistic, 2),
    "d_relu": (d_relu, 2),
    "d_tanh": (d_tanh, 2),
    "d_exp": (d_exp, 2),
    "d_log": (d_log, 2),
    "d_square": (d_square, 2),
    "d_sqrt": (d_sqrt, 2),
    "d_softmax_rows": (d_softmax_rows, 2),
    "broadcast_fst": (broadcast_fst, 2),
    "broadcast_rows_fst": (broadcast_rows_fst, 2),
    "d_div_l": (d_div_l, 2),
    "d_div_r": (d_div_r, 2),
    "d_bce_dyhat": (d_bce_dyhat, 2),
    "d_squared_diff_l": (d_squared_diff_l, 2),
    "d_softmax_xent_dl": (d_softmax_xent_dl, 2),
}


def shape_sets(chunk: int, label_cols: int) -> dict[str, list[tuple]]:
    """Input-shape sets to AOT-compile per kernel.

    `chunk` is the square block edge (default 64); `label_cols` the label
    width used by GCN losses. Shapes are (rows, cols) per operand.
    """
    c = chunk
    lc = label_cols
    sq = (c, c)
    col = (c, 1)
    lab = (c, lc)
    ew_shapes = [(sq, sq), (col, col), (lab, lab)]
    row = (1, c)       # per-node embedding rows (GCN message passing)
    rlab = (1, lc)
    return {
        "scalar_mul": [(((1, 1)), row), ((1, 1), rlab), ((1, 1), sq)],
        "sum_mul": [(row, row), (sq, sq)],
        "add": ew_shapes + [(row, row)],
        "sub": ew_shapes,
        "mul": ew_shapes,
        "div": ew_shapes,
        "matmul": [(sq, sq), (sq, lab), (sq, col), ((1, c), sq), ((1, c), (c, lc))],
        "matmul_tn": [(sq, sq), (sq, lab), (lab, lab), (row, row), (row, rlab)],
        "matmul_nt": [(sq, sq), (lab, lab), (rlab, (c, lc))],
        "bce_loss": [(col, col), ((1, 1), (1, 1))],
        "squared_diff": ew_shapes,
        "softmax_xent_rows": [(lab, lab), (rlab, rlab)],
        "row_broadcast_mul": [(col, sq), (col, lab)],
        "neg": [(sq,), (col,), (lab,)],
        "logistic": [(sq,), (col,)],
        "relu": [(sq,), (lab,), (col,), (row,)],
        "tanh": [(sq,)],
        "exp": [(sq,), (col,)],
        "log": [(col,)],
        "square": [(sq,), (col,)],
        "sqrt": [(col,)],
        "sum_all": [(sq,), (col,), (lab,)],
        "row_sum": [(sq,), (lab,)],
        "softmax_rows": [(lab,)],
        "transpose": [(sq,), (lab,)],
        "d_logistic": [(sq, sq), (col, col)],
        "d_relu": [(sq, sq), (lab, lab), (col, col), (row, row)],
        "d_tanh": [(sq, sq)],
        "d_exp": [(sq, sq), (col, col)],
        "d_log": [(col, col)],
        "d_square": [(sq, sq), (col, col)],
        "d_sqrt": [(col, col)],
        "d_softmax_rows": [(lab, lab)],
        "broadcast_fst": [((1, 1), sq), ((1, 1), col), ((1, 1), lab)],
        "broadcast_rows_fst": [(col, sq), (col, lab)],
        "d_div_l": ew_shapes,
        "d_div_r": ew_shapes,
        "d_bce_dyhat": [(col, col), ((1, 1), (1, 1))],
        "d_squared_diff_l": ew_shapes,
        "d_softmax_xent_dl": [(lab, lab), (rlab, rlab)],
    }
