"""L1: blocked matrix-multiply Pallas kernel.

This is the compute hot-spot of every workload in the paper (GCN layers,
NNMF factor updates, TransR projections all reduce to chunk matmuls inside
relational joins). The kernel tiles ``(M,K) x (K,N)`` into
``(bm,bk) / (bk,bn)`` VMEM blocks over a ``(M/bm, N/bn, K/bk)`` grid and
accumulates into the output block — the BlockSpec expresses the HBM<->VMEM
schedule that a CUDA implementation would express with threadblocks.

``interpret=True`` is mandatory on the CPU PJRT plugin (real TPU lowering
emits a Mosaic custom-call the CPU client cannot run); the artifacts built
from this kernel therefore execute as plain HLO, and real-TPU performance
is *estimated* from the block shapes in DESIGN.md / EXPERIMENTS.md §Perf.

VMEM footprint per grid step (f32): bm*bk + bk*bn + bm*bn floats.
Defaults (32,32,32) -> 12 KiB, far under the ~16 MiB VMEM budget; the
64-wide variants used for chunk-64 artifacts stay <= 48 KiB and keep both
MXU dimensions (128x128 systolic array on TPUv4; 8x128 VPU lanes) busy
when run in bf16 on hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output block; K-dimension iterated by the grid."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(x, y, *, bm: int = 32, bn: int = 32, bk: int = 32):
    """Blocked pallas matmul; shapes must divide the block sizes."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner-dim mismatch {x.shape} @ {y.shape}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, p: (i, p)),
            pl.BlockSpec((bk, bn), lambda i, j, p: (p, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, y)


def pick_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Largest power-of-two blocks (<=32) dividing each dimension."""

    def blk(d: int) -> int:
        b = 1
        while b < 32 and d % (b * 2) == 0:
            b *= 2
        return b

    return blk(m), blk(k), blk(n)


def matmul(x, y):
    """Matmul routed through the Pallas kernel when the shape tiles
    cleanly, else a plain ``jnp.dot`` (tiny edge chunks, vectors)."""
    m, k = x.shape
    _, n = y.shape
    bm, bk, bn = pick_blocks(m, k, n)
    if min(bm, bk, bn) >= 8:
        return matmul_pallas(x, y, bm=bm, bn=bn, bk=bk)
    return jnp.dot(x, y)
