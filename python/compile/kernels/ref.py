"""Pure-jnp oracle implementations of every chunk kernel.

These definitions are the single source of truth for kernel semantics on
the Python side: the Pallas kernel (L1) and the AOT-exported kernels (L2,
`model.py`) are pytest-verified against them, and the explicit derivative
kernels are verified against `jax.grad` of the forward ones.
Names match `rust/src/kernels/mod.rs::BinaryKernel/UnaryKernel` names.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- unary

def identity(x):
    return x


def neg(x):
    return -x


def logistic(x):
    return jax.nn.sigmoid(x)


def relu(x):
    return jnp.maximum(x, 0.0)


def tanh(x):
    return jnp.tanh(x)


def exp(x):
    return jnp.exp(x)


def log(x):
    return jnp.log(jnp.maximum(x, 1e-12))


def square(x):
    return x * x


def sqrt(x):
    return jnp.sqrt(jnp.maximum(x, 0.0))


def sum_all(x):
    return jnp.sum(x).reshape(1, 1)


def row_sum(x):
    return jnp.sum(x, axis=1, keepdims=True)


def softmax_rows(x):
    return jax.nn.softmax(x, axis=1)


def transpose(x):
    return x.T


# --------------------------------------------------------------- binary

def add(l, r):
    return l + r


def sub(l, r):
    return l - r


def mul(l, r):
    return l * r


def div(l, r):
    return l / r


def matmul(l, r):
    return jnp.dot(l, r)


def matmul_tn(l, r):
    return jnp.dot(l.T, r)


def matmul_nt(l, r):
    return jnp.dot(l, r.T)


def bce_loss(yhat, y):
    """Paper's ⊗Loss: -y·log(yhat) + (y-1)·log(1-yhat)."""
    yh = jnp.clip(yhat, 1e-7, 1.0 - 1e-7)
    return -y * jnp.log(yh) + (y - 1.0) * jnp.log(1.0 - yh)


def squared_diff(l, r):
    return (l - r) ** 2


def softmax_xent_rows(logits, onehot):
    logp = jax.nn.log_softmax(logits, axis=1)
    return -jnp.sum(onehot * logp, axis=1, keepdims=True)


def row_broadcast_mul(l, r):
    return l * r  # l is (rows, 1): numpy broadcasting


def scalar_mul(l, r):
    return l.reshape(1, 1) * r  # l is (1,1)


def sum_mul(g, x):
    return jnp.sum(g * x).reshape(1, 1)


# ---------------------------------------------------- derivative kernels
# Applied as k(g, x) (unary vjps) or k(l, r) (binary partials), mirroring
# rust's VjpSpec conventions.

def d_logistic(g, x):
    s = jax.nn.sigmoid(x)
    return g * s * (1.0 - s)


def d_relu(g, x):
    return g * (x > 0.0).astype(g.dtype)


def d_tanh(g, x):
    t = jnp.tanh(x)
    return g * (1.0 - t * t)


def d_exp(g, x):
    return g * jnp.exp(x)


def d_log(g, x):
    return g / jnp.maximum(x, 1e-12)


def d_square(g, x):
    return 2.0 * x * g


def d_sqrt(g, x):
    return g / (2.0 * jnp.sqrt(jnp.maximum(x, 1e-12)))


def d_softmax_rows(g, x):
    y = jax.nn.softmax(x, axis=1)
    return y * (g - jnp.sum(g * y, axis=1, keepdims=True))


def broadcast_fst(g, x):
    return jnp.broadcast_to(g.reshape(1, 1), x.shape)


def broadcast_rows_fst(g, x):
    return jnp.broadcast_to(g, x.shape)


def d_div_l(l, r):
    return 1.0 / r


def d_div_r(l, r):
    return -l / (r * r)


def d_bce_dyhat(yhat, y):
    yh = jnp.clip(yhat, 1e-7, 1.0 - 1e-7)
    return (yh - y) / (yh * (1.0 - yh))


def d_squared_diff_l(l, r):
    return 2.0 * (l - r)


def d_softmax_xent_dl(logits, onehot):
    return jax.nn.softmax(logits, axis=1) - onehot
